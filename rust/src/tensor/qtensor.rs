//! Packed low-bit integer tensors — the on-disk and in-engine form of a
//! quantized weight.
//!
//! A [`QTensor`] stores the integer grid values produced by RTN/SQuant
//! (symmetric per-output-channel grids, see `quant::qrange`) in packed
//! bytes: one `i8` per element for 5..=8-bit grids ("q8"), or two values
//! per byte for 2..=4-bit grids ("q4", packed per row so rows stay
//! byte-aligned and odd row lengths get a zero tail nibble).  Alongside the
//! payload it carries the per-channel f32 scales and the per-row grid-value
//! sums the integer GEMM epilogue needs for activation zero-point
//! correction (`tensor::qgemm`).
//!
//! Dequantization (`q * scale[row]`) is bit-identical to `quant::dequant`
//! on the same grid, so a packed artifact reconstructs the exact f32
//! weights the fake-quant path would have stored.
//!
//! Every QTensor also carries a kernel-native [`PackedWeights`] panel
//! buffer, built exactly once at construction time (`from_grid` at
//! assemble time, `from_packed` at disk load): rows laid out as
//! [`MR`]-row panels with the k dimension interleaved across lanes, i4
//! nibbles already sign-extended to i8, and the per-panel scale /
//! row-sum slices the blocked GEMM epilogue walks.  The per-GEMM nibble
//! decode and row copy the row-at-a-time kernel paid are gone.

use super::Tensor;
use anyhow::{bail, Result};

/// Largest grid bit-width a QTensor can represent (i8 storage).
pub const MAX_PACK_BITS: usize = 8;

/// Rows per weight panel — the microkernel's register-block height.
/// Shared with `tensor::qgemm`; changing it re-layouts every panel.
pub const MR: usize = 4;

/// Kernel-native panel layout of a QTensor's rows, built once at
/// construction.  Rows are grouped into `npanels = rows.div_ceil(MR)`
/// panels; within a panel the k dimension is the major axis and the MR
/// row lanes are interleaved: `data[(p*k + kk)*MR + r]` is row `p*MR+r`,
/// column `kk`.  Tail lanes of the last panel are zero-filled (zero grid
/// values contribute nothing to the accumulator, and the epilogue never
/// writes rows past `rows`).  `scales`/`row_sums` are the per-row values
/// padded to `npanels * MR` so the epilogue can take exact per-panel
/// slices instead of bounds-checking `scales[row]` per element.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PackedWeights {
    /// Row-panel count: `rows.div_ceil(MR)`.
    pub npanels: usize,
    /// Elements per row (the GEMM k dimension).
    pub k: usize,
    /// Panel-major sign-extended grid values, `npanels * k * MR` long.
    pub data: Vec<i8>,
    /// Per-row scales padded to `npanels * MR` (tail lanes 0.0).
    pub scales: Vec<f32>,
    /// Per-row grid-value sums padded to `npanels * MR` (tail lanes 0).
    pub row_sums: Vec<i32>,
}

impl PackedWeights {
    /// Heap footprint of the panel buffer (payload + padded scales/sums).
    pub fn bytes(&self) -> usize {
        self.data.len() + 4 * self.scales.len() + 4 * self.row_sums.len()
    }
}

/// Packed integer tensor: grid values + per-output-channel scales.
#[derive(Clone, Debug, PartialEq)]
pub struct QTensor {
    /// Logical shape of the weight — conv `[O, I/g, KH, KW]` or linear
    /// `[O, I]`.  `shape[0]` is the output-channel (row) axis.
    pub shape: Vec<usize>,
    /// Grid bit-width the values were quantized to (2..=8).
    pub bits: usize,
    /// Packed payload (see module docs for the q4/q8 layouts).
    pub data: Vec<u8>,
    /// Per-output-channel dequantize scales, `len == shape[0]`.
    pub scales: Vec<f32>,
    /// Per-row sums of grid values: the qgemm epilogue's zero-point
    /// correction term (`Σ wq·(q−zp) = Σ wq·q − zp·Σ wq`).
    pub row_sums: Vec<i32>,
    /// Kernel-native panel layout, built once at construction time so the
    /// blocked GEMM never unpacks nibbles or copies rows per call.  A pure
    /// function of the other fields, so `PartialEq`/round-trips still hold.
    pub packed: PackedWeights,
}

impl QTensor {
    /// Storage width in bits: 4 (nibble-packed) for grids up to 4 bits,
    /// else 8 (one byte per element).
    pub fn storage_bits(&self) -> usize {
        storage_bits(self.bits)
    }

    /// Number of rows (output channels).
    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    /// Elements per row.
    pub fn row_len(&self) -> usize {
        self.shape[1..].iter().product()
    }

    /// Packed bytes per row.
    pub fn row_bytes(&self) -> usize {
        row_bytes(self.bits, self.row_len())
    }

    /// Approximate heap footprint (payload + scales + row sums + the
    /// pre-packed kernel panels + headers), mirroring
    /// `serve::cache::tensor_bytes` for the f32 case.  Cache unique-bytes
    /// accounting charges the panel buffer through this.
    pub fn bytes(&self) -> usize {
        self.data.len()
            + 4 * self.scales.len()
            + 4 * self.row_sums.len()
            + self.packed.bytes()
            + 64
    }

    /// Pack a grid-value tensor (f32 integers from `quant::quantize_rtn` or
    /// SQuant's flip search) into a QTensor.  Rejects non-integral values,
    /// values outside the symmetric `bits` grid, and bad scale counts.
    pub fn from_grid(q: &Tensor, scales: &[f32], bits: usize) -> Result<QTensor> {
        if !(2..=MAX_PACK_BITS).contains(&bits) {
            bail!("qtensor bits {bits} out of range 2..={MAX_PACK_BITS}");
        }
        if q.shape.is_empty() {
            bail!("qtensor needs a shaped tensor");
        }
        let rows = q.shape[0];
        if scales.len() != rows {
            bail!("qtensor scales len {} vs {rows} rows", scales.len());
        }
        let per: usize = q.shape[1..].iter().product();
        let qmax = ((1i32 << (bits - 1)) - 1) as f32;
        let mut grid = vec![0i8; per];
        let rb = row_bytes(bits, per);
        let mut data = vec![0u8; rows * rb];
        let mut row_sums = vec![0i32; rows];
        for r in 0..rows {
            let src = &q.data[r * per..(r + 1) * per];
            let mut sum = 0i32;
            for (g, &v) in grid.iter_mut().zip(src) {
                if v != v.trunc() || !(-qmax..=qmax).contains(&v) {
                    bail!("grid value {v} not on the {bits}-bit integer grid");
                }
                *g = v as i8;
                sum += v as i32;
            }
            row_sums[r] = sum;
            pack_row(&grid, bits, &mut data[r * rb..(r + 1) * rb]);
        }
        let mut qt = QTensor {
            shape: q.shape.clone(),
            bits,
            data,
            scales: scales.to_vec(),
            row_sums,
            packed: PackedWeights::default(),
        };
        qt.packed = qt.prepack();
        Ok(qt)
    }

    /// Rebuild from already-packed bytes (the disk-load path).  Validates
    /// payload length and scale count, and recomputes `row_sums` from the
    /// payload so a corrupted sum can never silently skew the epilogue.
    pub fn from_packed(
        shape: Vec<usize>,
        bits: usize,
        data: Vec<u8>,
        scales: Vec<f32>,
    ) -> Result<QTensor> {
        if !(2..=MAX_PACK_BITS).contains(&bits) {
            bail!("qtensor bits {bits} out of range 2..={MAX_PACK_BITS}");
        }
        if shape.is_empty() {
            bail!("qtensor needs a shaped tensor");
        }
        let rows = shape[0];
        let per: usize = shape[1..].iter().product();
        let rb = row_bytes(bits, per);
        if data.len() != rows * rb {
            bail!("qtensor payload {} bytes, want {} ({rows}x{rb})", data.len(), rows * rb);
        }
        if scales.len() != rows {
            bail!("qtensor scales len {} vs {rows} rows", scales.len());
        }
        let mut qt = QTensor {
            shape,
            bits,
            data,
            scales,
            row_sums: vec![0; rows],
            packed: PackedWeights::default(),
        };
        let qmax = ((1i32 << (bits - 1)) - 1) as i8;
        let mut grid = vec![0i8; per];
        for r in 0..rows {
            qt.unpack_row(r, &mut grid);
            let mut sum = 0i32;
            for &g in &grid {
                if g < -qmax || g > qmax {
                    bail!("packed value {g} outside the {bits}-bit grid");
                }
                sum += g as i32;
            }
            qt.row_sums[r] = sum;
        }
        qt.packed = qt.prepack();
        Ok(qt)
    }

    /// Lay the rows out as MR-row kernel panels (see [`PackedWeights`]).
    /// Called exactly once per tensor, from both constructors — the one
    /// place i4 nibbles are ever decoded on the inference path.
    fn prepack(&self) -> PackedWeights {
        let rows = self.rows();
        let k = self.row_len();
        let npanels = rows.div_ceil(MR);
        let mut data = vec![0i8; npanels * k * MR];
        let mut scales = vec![0.0f32; npanels * MR];
        let mut row_sums = vec![0i32; npanels * MR];
        let mut grid = vec![0i8; k];
        for r in 0..rows {
            self.unpack_row(r, &mut grid);
            let base = (r / MR) * k * MR + (r % MR);
            for (kk, &g) in grid.iter().enumerate() {
                data[base + kk * MR] = g;
            }
            scales[r] = self.scales[r];
            row_sums[r] = self.row_sums[r];
        }
        PackedWeights { npanels, k, data, scales, row_sums }
    }

    /// Unpack row `r` into `dst[..row_len()]` as sign-extended i8 values.
    pub fn unpack_row(&self, r: usize, dst: &mut [i8]) {
        let per = self.row_len();
        let dst = &mut dst[..per];
        if self.storage_bits() == 8 {
            for (d, &b) in dst.iter_mut().zip(&self.data[r * per..(r + 1) * per]) {
                *d = b as i8;
            }
        } else {
            let rb = self.row_bytes();
            let row = &self.data[r * rb..(r + 1) * rb];
            let mut i = 0;
            for &b in row {
                dst[i] = ((b << 4) as i8) >> 4;
                if i + 1 < per {
                    dst[i + 1] = (b as i8) >> 4;
                }
                i += 2;
            }
        }
    }

    /// Unpacked grid values as an f32 tensor (inverse of [`from_grid`]).
    pub fn to_grid(&self) -> Tensor {
        let per = self.row_len();
        let mut out = Tensor::zeros(&self.shape);
        let mut grid = vec![0i8; per];
        for r in 0..self.rows() {
            self.unpack_row(r, &mut grid);
            for (o, &g) in out.data[r * per..(r + 1) * per].iter_mut().zip(&grid) {
                *o = g as f32;
            }
        }
        out
    }

    /// Dequantize to f32 weights — bit-identical to `quant::dequant` on the
    /// same grid (`w = q * scale[row]`, one f32 multiply per element).
    pub fn dequantize(&self) -> Tensor {
        let per = self.row_len();
        let mut out = Tensor::zeros(&self.shape);
        let mut grid = vec![0i8; per];
        for r in 0..self.rows() {
            self.unpack_row(r, &mut grid);
            let s = self.scales[r];
            for (o, &g) in out.data[r * per..(r + 1) * per].iter_mut().zip(&grid) {
                *o = g as f32 * s;
            }
        }
        out
    }
}

/// Storage width for a grid bit-width: nibble-packed up to 4 bits, else i8.
pub fn storage_bits(bits: usize) -> usize {
    if bits <= 4 {
        4
    } else {
        8
    }
}

/// Packed bytes for one row of `per` elements at `bits`.
pub fn row_bytes(bits: usize, per: usize) -> usize {
    if storage_bits(bits) == 4 {
        per.div_ceil(2)
    } else {
        per
    }
}

fn pack_row(grid: &[i8], bits: usize, dst: &mut [u8]) {
    if storage_bits(bits) == 8 {
        for (d, &g) in dst.iter_mut().zip(grid) {
            *d = g as u8;
        }
    } else {
        for (d, pair) in dst.iter_mut().zip(grid.chunks(2)) {
            let lo = (pair[0] as u8) & 0x0f;
            let hi = if pair.len() > 1 { (pair[1] as u8) & 0x0f } else { 0 };
            *d = lo | (hi << 4);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn random_grid(c: &mut crate::util::prop::Case, rows: usize, per: usize, bits: usize) -> Tensor {
        let qmax = (1i32 << (bits - 1)) - 1;
        let span = (2 * qmax + 1) as usize;
        let data: Vec<f32> =
            (0..rows * per).map(|_| (c.rng.below(span) as i32 - qmax) as f32).collect();
        Tensor::from_vec(&[rows, per], data)
    }

    #[test]
    fn pack_unpack_round_trip_property() {
        // i8 and i4 storage, odd row lengths included (nibble tails).
        forall("qtensor-round-trip", 11, 80, 37, |c| {
            let rows = 1 + c.rng.below(5);
            let per = c.size;
            let bits = [2, 3, 4, 5, 8][c.rng.below(5)];
            let q = random_grid(c, rows, per, bits);
            let scales: Vec<f32> = (0..rows).map(|r| 0.01 + r as f32 * 0.003).collect();
            let qt = QTensor::from_grid(&q, &scales, bits).map_err(|e| e.to_string())?;
            if qt.to_grid() != q {
                return Err(format!("grid mismatch bits={bits} rows={rows} per={per}"));
            }
            for r in 0..rows {
                let want: i32 = q.data[r * per..(r + 1) * per].iter().map(|&v| v as i32).sum();
                if qt.row_sums[r] != want {
                    return Err(format!("row_sums[{r}] {} vs {want}", qt.row_sums[r]));
                }
            }
            // Disk-load path rebuilds the identical tensor from raw bytes.
            let rebuilt =
                QTensor::from_packed(qt.shape.clone(), bits, qt.data.clone(), qt.scales.clone())
                    .map_err(|e| e.to_string())?;
            if rebuilt != qt {
                return Err("from_packed differs from from_grid".into());
            }
            Ok(())
        });
    }

    #[test]
    fn dequantize_matches_quant_dequant_bitwise() {
        use crate::quant::{channel_scales, dequant, quantize_rtn, QuantConfig};
        use crate::util::rng::Rng;
        let mut rng = Rng::new(7);
        for &bits in &[4usize, 8] {
            let mut w = Tensor::zeros(&[3, 2, 3, 3]);
            rng.fill_normal(&mut w.data, 0.2);
            let scales = channel_scales(&w, QuantConfig::new(bits));
            let q = quantize_rtn(&w, &scales, bits);
            let qt = QTensor::from_grid(&q, &scales, bits).unwrap();
            assert_eq!(qt.dequantize().data, dequant(&q, &scales).data);
        }
    }

    #[test]
    fn q4_packs_two_per_byte_with_zero_tail() {
        let q = Tensor::from_vec(&[1, 5], vec![-7.0, 7.0, -1.0, 0.0, 3.0]);
        let qt = QTensor::from_grid(&q, &[1.0], 4).unwrap();
        assert_eq!(qt.storage_bits(), 4);
        assert_eq!(qt.data.len(), 3); // ceil(5/2)
        assert_eq!(qt.data[0], 0x79); // lo=-7 (0b1001), hi=7 (0b0111)
        assert_eq!(qt.data[2] >> 4, 0, "odd tail nibble must be zero");
        assert_eq!(qt.row_sums, vec![2]);
    }

    #[test]
    fn q8_is_one_byte_per_element() {
        let q = Tensor::from_vec(&[2, 3], vec![-127.0, 0.0, 127.0, 1.0, -1.0, 64.0]);
        let qt = QTensor::from_grid(&q, &[0.5, 0.25], 8).unwrap();
        assert_eq!(qt.storage_bits(), 8);
        assert_eq!(qt.data.len(), 6);
        assert_eq!(qt.data[0] as i8, -127);
        assert_eq!(qt.row_sums, vec![0, 64]);
        assert_eq!(qt.dequantize().data, vec![-63.5, 0.0, 63.5, 0.25, -0.25, 16.0]);
    }

    #[test]
    fn from_grid_rejects_bad_inputs() {
        let q = Tensor::from_vec(&[1, 2], vec![0.5, 1.0]);
        assert!(QTensor::from_grid(&q, &[1.0], 4).is_err(), "non-integral grid");
        let q = Tensor::from_vec(&[1, 2], vec![9.0, 0.0]);
        assert!(QTensor::from_grid(&q, &[1.0], 4).is_err(), "out of 4-bit range");
        let q = Tensor::from_vec(&[1, 2], vec![1.0, 0.0]);
        assert!(QTensor::from_grid(&q, &[1.0, 2.0], 4).is_err(), "scales len");
        assert!(QTensor::from_grid(&q, &[1.0], 9).is_err(), "bits too wide");
        assert!(QTensor::from_grid(&q, &[1.0], 1).is_err(), "bits too narrow");
    }

    #[test]
    fn prepack_panel_layout_interleaves_mr_lanes() {
        // 5 rows of 3 elements → 2 panels; the second panel's 3 unused
        // lanes (and padded scales/sums) must be zero.
        let vals: Vec<f32> = (0..15).map(|i| (i as i32 - 7) as f32).collect();
        let q = Tensor::from_vec(&[5, 3], vals.clone());
        let scales: Vec<f32> = (0..5).map(|r| 1.0 + r as f32).collect();
        let qt = QTensor::from_grid(&q, &scales, 4).unwrap();
        let pw = &qt.packed;
        assert_eq!((pw.npanels, pw.k), (2, 3));
        assert_eq!(pw.data.len(), 2 * 3 * MR);
        assert_eq!(pw.scales.len(), 2 * MR);
        for r in 0..5 {
            for kk in 0..3 {
                let lane = pw.data[((r / MR) * 3 + kk) * MR + (r % MR)];
                assert_eq!(lane as f32, vals[r * 3 + kk], "row {r} col {kk}");
            }
            assert_eq!(pw.scales[r], scales[r]);
            assert_eq!(pw.row_sums[r], qt.row_sums[r]);
        }
        for kk in 0..3 {
            for lane in 1..MR {
                assert_eq!(pw.data[(3 + kk) * MR + lane], 0, "tail lane");
            }
        }
        assert_eq!(&pw.scales[5..], &[0.0, 0.0, 0.0]);
        assert_eq!(&pw.row_sums[5..], &[0, 0, 0]);
    }

    #[test]
    fn from_packed_rejects_bad_payload() {
        let q = Tensor::from_vec(&[2, 2], vec![1.0, -1.0, 2.0, -2.0]);
        let qt = QTensor::from_grid(&q, &[1.0, 1.0], 4).unwrap();
        let bad_len = QTensor::from_packed(
            qt.shape.clone(),
            4,
            qt.data[..1].to_vec(),
            qt.scales.clone(),
        );
        assert!(bad_len.is_err());
        // A q4 byte decoding to -8 is off the symmetric grid (qmin = -7).
        let bad_val = QTensor::from_packed(vec![1, 1], 4, vec![0x08], vec![1.0]);
        assert!(bad_val.is_err());
    }
}
