//! Empirical Hessian analysis — the machinery behind the paper's Appendix
//! A.3 / Table 6 (approximation precision) and Figure 1 (decomposition
//! coverage).
//!
//! For a conv layer, the per-output-channel expected Hessian is
//! `E[H] ≈ l_m · E[x xᵀ]` (Eq. 2) where x ranges over im2col columns of the
//! layer input.  We estimate `E[x xᵀ]` from captured activations on real
//! (or synthetic) data, decompose it with Algorithm 3, and judge every flip
//! SQuant performed against the *precise* objective Eq. (6): a flip is
//! "correct" when it decreases the coefficient-weighted objective, and the
//! approximation precision AP = correct / flipped.

use anyhow::{bail, Result};

use crate::nn::{Graph, Op};
use crate::squant::decompose::{decompose, Decomposition};
use crate::squant::{squant_traced, SquantOpts, SquantResult};
use crate::tensor::im2col::im2col;
use crate::tensor::Tensor;

/// Accumulate E[x xᵀ] for one conv layer from a batch of its input
/// activations (B, C, H, W).  `max_cols` subsamples im2col columns to bound
/// cost.  Only groups == 1 convs are supported (the Table 6 target,
/// ResNet18, is group-free).
#[allow(clippy::too_many_arguments)]
pub fn empirical_xxt(
    inputs: &Tensor,
    kh: usize,
    kw: usize,
    stride: usize,
    ph: usize,
    pw: usize,
    max_cols: usize,
) -> Tensor {
    let (b, c, h, w) = (
        inputs.shape[0],
        inputs.shape[1],
        inputs.shape[2],
        inputs.shape[3],
    );
    let nk = c * kh * kw;
    let mut acc = Tensor::zeros(&[nk, nk]);
    let mut count = 0usize;
    for bi in 0..b {
        let img = &inputs.data[bi * c * h * w..(bi + 1) * c * h * w];
        let patches = im2col(img, c, h, w, kh, kw, stride, ph, pw);
        let cols = patches.shape[1];
        let step = (cols * b / max_cols.max(1)).max(1);
        let mut ci = (bi * 7) % step; // stagger sampling across images
        while ci < cols {
            // x x^T accumulate (upper triangle, mirrored after).
            for r in 0..nk {
                let xr = patches.at2(r, ci);
                if xr == 0.0 {
                    continue;
                }
                let arow = &mut acc.data[r * nk..(r + 1) * nk];
                for cc in 0..nk {
                    arow[cc] += xr * patches.at2(cc, ci);
                }
            }
            count += 1;
            ci += step;
        }
    }
    if count > 0 {
        acc.scale_inplace(1.0 / count as f32);
    }
    acc
}

/// The per-stage flip judgement for one layer (one Table 6 row).
#[derive(Clone, Copy, Debug, Default)]
pub struct ApStats {
    pub k_flipped: usize,
    pub k_correct: usize,
    pub c_flipped: usize,
    pub c_correct: usize,
}

impl ApStats {
    pub fn k_ap(&self) -> f64 {
        if self.k_flipped == 0 {
            1.0
        } else {
            self.k_correct as f64 / self.k_flipped as f64
        }
    }
    pub fn c_ap(&self) -> f64 {
        if self.c_flipped == 0 {
            1.0
        } else {
            self.c_correct as f64 / self.c_flipped as f64
        }
    }
}

/// Judge every flip of a traced SQuant run against the precise objective
/// Eq. (6) with coefficients from `decomp` (shared across output channels,
/// per Eq. 2 — the positive per-channel factor l_m cancels in the sign).
pub fn judge_flips(
    w: &Tensor,
    res: &SquantResult,
    decomp: &Decomposition,
) -> ApStats {
    let (m, n, k) = crate::quant::mnk_of(&w.shape);
    assert_eq!((decomp.n, decomp.k), (n, k));

    // Rebuild the RTN starting state.
    let q0 = crate::quant::quantize_rtn(w, &res.scales, res.bits);
    let mut p = crate::quant::perturbation(w, &q0, &res.scales);
    let mut ker_sum = vec![0.0f32; m * n];
    let mut chan_sum = vec![0.0f32; m];
    for mi in 0..m {
        for ni in 0..n {
            let s: f32 = p.data[(mi * n + ni) * k..(mi * n + ni + 1) * k]
                .iter()
                .sum();
            ker_sum[mi * n + ni] = s;
            chan_sum[mi] += s;
        }
    }

    let mut st = ApStats::default();
    for ev in &res.trace {
        let off = (ev.m * n + ev.n) * k + ev.i;
        let d = ev.delta;
        let pv = p.data[off];
        let sk = ker_sum[ev.m * n + ev.n];
        let tc = chan_sum[ev.m];
        // Eq. (6) delta for a single +-1 mutation.
        let delta_obj = decomp.e(ev.n, ev.i) * ((pv + d) * (pv + d) - pv * pv)
            + decomp.kern[ev.n] * ((sk + d) * (sk + d) - sk * sk)
            + decomp.c * ((tc + d) * (tc + d) - tc * tc);
        let correct = delta_obj < 0.0;
        if ev.c_stage {
            st.c_flipped += 1;
            st.c_correct += correct as usize;
        } else {
            st.k_flipped += 1;
            st.k_correct += correct as usize;
        }
        p.data[off] += d;
        ker_sum[ev.m * n + ev.n] += d;
        chan_sum[ev.m] += d;
    }
    st
}

/// One Table-6 row: layer id + AP for SQuant-E&K and SQuant-E&K&C stages.
#[derive(Clone, Debug)]
pub struct LayerAp {
    pub node_id: usize,
    pub name: String,
    pub stats: ApStats,
}

/// Conv attributes needed to compute the empirical Hessian of a layer.
pub struct ConvAttrs {
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub ph: usize,
    pub pw: usize,
}

pub fn conv_attrs(graph: &Graph, node_id: usize) -> Result<ConvAttrs> {
    match &graph.nodes[node_id].op {
        Op::Conv2d { kh, kw, stride, ph, pw, groups, .. } => {
            if *groups != 1 {
                bail!("empirical Hessian only for groups == 1");
            }
            Ok(ConvAttrs { kh: *kh, kw: *kw, stride: *stride, ph: *ph, pw: *pw })
        }
        _ => bail!("node {node_id} is not a conv"),
    }
}

/// Full per-layer AP analysis given the layer's captured input activations.
pub fn layer_ap(
    w: &Tensor,
    scales: &[f32],
    bits: usize,
    inputs: &Tensor,
    attrs: &ConvAttrs,
    max_cols: usize,
) -> (ApStats, Decomposition) {
    let (_, n, k) = crate::quant::mnk_of(&w.shape);
    let h = empirical_xxt(inputs, attrs.kh, attrs.kw, attrs.stride, attrs.ph,
                          attrs.pw, max_cols);
    let decomp = decompose(&h, n, k);
    let res = squant_traced(w, scales, SquantOpts::full(bits));
    (judge_flips(w, &res, &decomp), decomp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{channel_scales, QuantConfig};
    use crate::util::rng::Rng;

    #[test]
    fn xxt_identity_input() {
        // Single 1x1 "image" with value v: H = v^2 J for 1x1 kernel.
        let x = Tensor::from_vec(&[1, 2, 1, 1], vec![2.0, 3.0]);
        let h = empirical_xxt(&x, 1, 1, 1, 0, 0, 100);
        assert_eq!(h.shape, vec![2, 2]);
        assert!((h.at2(0, 0) - 4.0).abs() < 1e-6);
        assert!((h.at2(0, 1) - 6.0).abs() < 1e-6);
        assert!((h.at2(1, 1) - 9.0).abs() < 1e-6);
    }

    #[test]
    fn xxt_symmetric_psd_diag() {
        let mut rng = Rng::new(1);
        let mut x = Tensor::zeros(&[2, 3, 6, 6]);
        rng.fill_normal(&mut x.data, 1.0);
        let h = empirical_xxt(&x, 3, 3, 1, 1, 1, 64);
        let nk = 27;
        for r in 0..nk {
            assert!(h.at2(r, r) >= -1e-6);
            for c in 0..nk {
                assert!((h.at2(r, c) - h.at2(c, r)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn judge_manual_single_flip() {
        // One kernel, one channel: w/s = [1.3, 0.45, 0.45] -> RTN q = [1,0,0],
        // p = [-0.3, -0.45, -0.45], e = -1.2 -> one flip of index 1 (largest
        // |p|, tie to lower index), d = +1.
        let w = Tensor::from_vec(&[1, 1, 1, 3], vec![1.3, 0.45, 0.45]);
        let scales = vec![1.0];
        let res = squant_traced(&w, &scales, SquantOpts::full(4));
        assert_eq!(res.trace.len(), 1);
        let ev = res.trace[0];
        assert_eq!((ev.n, ev.i, ev.delta), (0, 1, 1.0));
        // Judge under coefficients where the kernel term dominates:
        // delta = e*((p+1)^2 - p^2) + k*((S+1)^2 - S^2) + c*(same as k)
        //       = e*(0.55^2-0.45^2) + (k+c)*((-0.2)^2-(-1.2)^2)
        //       = 0.1*e - 1.4*(k+c)
        let mk = |e: f32, k: f32, c: f32| Decomposition {
            n: 1, k: 3, c, kern: vec![k], elem: vec![e; 3],
        };
        let ap = judge_flips(&w, &res, &mk(0.1, 1.0, 0.5));
        assert_eq!((ap.k_flipped, ap.k_correct), (1, 1));
        // Element term dominant -> the same flip is judged incorrect.
        let ap = judge_flips(&w, &res, &mk(100.0, 0.01, 0.01));
        assert_eq!((ap.k_flipped, ap.k_correct), (1, 0));
    }

    fn synth_acts(s_amp: f32, c_amp: f32, n_amp: f32, mu: f32) -> Tensor {
        let mut rng = Rng::new(7);
        let mut x = Tensor::zeros(&[6, 6, 8, 8]);
        for bi in 0..6 {
            let shared = rng.normal();
            for ci in 0..6 {
                let chan = rng.normal();
                let off = (bi * 6 + ci) * 64;
                for i in 0..64 {
                    x.data[off + i] =
                        mu + shared * s_amp + chan * c_amp + rng.normal() * n_amp;
                }
            }
        }
        x
    }

    fn ap_of(x: &Tensor) -> ApStats {
        let mut rng = Rng::new(3);
        let mut w = Tensor::zeros(&[8, 6, 3, 3]);
        rng.fill_normal(&mut w.data, 0.1);
        let scales = channel_scales(&w, QuantConfig::new(4));
        let attrs = ConvAttrs { kh: 3, kw: 3, stride: 1, ph: 1, pw: 1 };
        layer_ap(&w, &scales, 4, x, &attrs, 128).0
    }

    #[test]
    fn ap_tracks_hessian_structure() {
        // The approximation precision must respond to the activation
        // covariance structure exactly as the paper's theory predicts
        // (Appendix A.1): per-channel-correlated activations validate the
        // kernel-wise term (high K AP), a strong shared component validates
        // the channel-wise term (high C AP), and iid activations break the
        // kernel assumption (low K AP).
        let chan_dom = ap_of(&synth_acts(0.1, 1.0, 0.1, 0.5));
        assert!(chan_dom.k_flipped > 0);
        assert!(chan_dom.k_ap() >= 0.85, "chan-dom K AP {}", chan_dom.k_ap());

        let shared_dom = ap_of(&synth_acts(1.0, 0.1, 0.05, 1.0));
        assert!(shared_dom.c_ap() >= 0.75, "shared-dom C AP {}", shared_dom.c_ap());

        let iid = ap_of(&synth_acts(0.0, 0.0, 1.0, 0.0));
        assert!(iid.k_ap() < 0.5, "iid K AP {}", iid.k_ap());

        // Realistic mixed structure (post-BN/ReLU-like): K stage decent.
        // (C flips are too few per layer for a stable AP assertion here —
        // the Table 6 bench measures it on the real model.)
        let mixed = ap_of(&synth_acts(0.5, 0.5, 0.2, 0.8));
        assert!(mixed.k_ap() >= 0.7, "mixed K AP {}", mixed.k_ap());
    }

    #[test]
    fn judge_flips_counts_match_trace() {
        let mut rng = Rng::new(5);
        let mut w = Tensor::zeros(&[4, 3, 3, 3]);
        rng.fill_normal(&mut w.data, 0.1);
        let scales = channel_scales(&w, QuantConfig::new(4));
        let res = squant_traced(&w, &scales, SquantOpts::full(4));
        // Uniform H: every coefficient equal.
        let h = Tensor::filled(&[27, 27], 1.0);
        let d = decompose(&h, 3, 9);
        let ap = judge_flips(&w, &res, &d);
        assert_eq!(ap.k_flipped, res.flips_k);
        assert_eq!(ap.c_flipped, res.flips_c);
    }
}
