//! Tiny CLI argument parser (no clap in the offline vendor set).
//!
//! Grammar: `squant <command> [--key value]... [--flag]... [positional]...`
//! Typed getters with defaults; unknown-flag detection via `finish()`.

use anyhow::{anyhow, bail, Result};
use std::collections::HashSet;

#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    opts: Vec<(String, String)>,
    flags: Vec<String>,
    pub positional: Vec<String>,
    consumed: HashSet<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1).collect())
    }

    pub fn parse(argv: Vec<String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.command = it.next();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --key=value or --key value or --flag
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.push((k.to_string(), v.to_string()));
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.opts.push((name.to_string(), it.next().unwrap()));
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn opt(&mut self, key: &str) -> Option<String> {
        self.consumed.insert(key.to_string());
        self.opts
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.clone())
    }

    pub fn str_or(&mut self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or_else(|| default.to_string())
    }

    pub fn usize_or(&mut self, key: &str, default: usize) -> Result<usize> {
        match self.opt(key) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
            None => Ok(default),
        }
    }

    pub fn f64_or(&mut self, key: &str, default: f64) -> Result<f64> {
        match self.opt(key) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
            None => Ok(default),
        }
    }

    pub fn u64_or(&mut self, key: &str, default: u64) -> Result<u64> {
        match self.opt(key) {
            Some(v) => v.parse().map_err(|e| anyhow!("--{key}: {e}")),
            None => Ok(default),
        }
    }

    pub fn flag(&mut self, key: &str) -> bool {
        self.consumed.insert(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Comma-separated list option.
    pub fn list_or(&mut self, key: &str, default: &str) -> Vec<String> {
        self.str_or(key, default)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| s.to_string())
            .collect()
    }

    /// Error on any option/flag that was never consumed (typo guard).
    pub fn finish(&self) -> Result<()> {
        for (k, _) in &self.opts {
            if !self.consumed.contains(k) {
                bail!("unknown option --{k}");
            }
        }
        for k in &self.flags {
            if !self.consumed.contains(k) {
                bail!("unknown flag --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()).collect())
    }

    #[test]
    fn command_and_opts() {
        let mut a = args("quantize --bits 4 --model miniresnet18 --verbose");
        assert_eq!(a.command.as_deref(), Some("quantize"));
        assert_eq!(a.usize_or("bits", 8).unwrap(), 4);
        assert_eq!(a.str_or("model", "x"), "miniresnet18");
        assert!(a.flag("verbose"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn eq_syntax() {
        let mut a = args("eval --bits=6");
        assert_eq!(a.usize_or("bits", 8).unwrap(), 6);
    }

    #[test]
    fn unknown_option_rejected() {
        let mut a = args("eval --bogus 3");
        let _ = a.usize_or("bits", 8);
        assert!(a.finish().is_err());
    }

    #[test]
    fn defaults() {
        let mut a = args("eval");
        assert_eq!(a.usize_or("bits", 8).unwrap(), 8);
        assert_eq!(a.f64_or("lr", 0.5).unwrap(), 0.5);
        assert!(!a.flag("force"));
    }

    #[test]
    fn list_parsing() {
        let mut a = args("t --models a,b,c");
        assert_eq!(a.list_or("models", ""), vec!["a", "b", "c"]);
    }

    #[test]
    fn last_occurrence_wins() {
        let mut a = args("t --bits 4 --bits 6");
        assert_eq!(a.usize_or("bits", 8).unwrap(), 6);
    }
}
