//! Parallel-execution helpers (no rayon in the offline vendor set).
//!
//! Two primitives:
//!  * [`parallel_for`] — scoped fork-join over an index range, used by the
//!    CLI-side coordinator shim to quantize layers/channels concurrently;
//!  * [`ThreadPool`] — a persistent pool with a *weighted* submission
//!    queue, used by the long-lived on-the-fly service.  Jobs carry a
//!    virtual-time key ([`ThreadPool::submit_at`]); workers always run the
//!    smallest key first, so layer tasks from concurrent requests
//!    interleave by predicted cost (start-time fair queueing) instead of
//!    strict FIFO head-of-line blocking.  Plain [`ThreadPool::submit`]
//!    enqueues at the current virtual time, which keeps unweighted jobs
//!    FIFO among themselves.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(i)` for every `i in 0..n`, work-stealing via an atomic counter.
/// `f` may produce a value; results are returned in index order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return (0..n).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let out_ptr = SendPtr(out.as_mut_ptr());
    thread::scope(|s| {
        for _ in 0..threads {
            let fref = &f;
            let nref = &next;
            let optr = &out_ptr;
            s.spawn(move || loop {
                let i = nref.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = fref(i);
                // SAFETY: each index i is claimed exactly once, slots are
                // disjoint, and the scope outlives all writes.
                unsafe {
                    *optr.0.add(i) = Some(v);
                }
            });
        }
    });
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// Run `f(i)` for side effects only.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_map(n, threads, |i| f(i));
}

struct SendPtr<T>(*mut T);
// SAFETY: used only with disjoint index writes inside a scope.
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

// ---------------------------------------------------------------------------
// Persistent pool
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

/// How a submission picks its virtual-time key (see [`ThreadPool`]).
enum Key {
    /// At the current virtual time (plain [`ThreadPool::submit`]).
    Now,
    /// Explicit key, clamped up to the current virtual time.
    At(u64),
    /// At the shared flow tag, advancing it by the given weight.
    Flow(u64),
}

/// One queued job ordered by (virtual-time key, submission seq).  The seq
/// tiebreak keeps equal-key jobs FIFO and makes the order total.
struct QueuedJob {
    key: u64,
    seq: u64,
    job: Job,
}

impl PartialEq for QueuedJob {
    fn eq(&self, other: &Self) -> bool {
        (self.key, self.seq) == (other.key, other.seq)
    }
}
impl Eq for QueuedJob {}
impl PartialOrd for QueuedJob {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QueuedJob {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.key, self.seq).cmp(&(other.key, other.seq))
    }
}

struct PoolState {
    /// Min-heap on (key, seq) via `Reverse`.
    heap: BinaryHeap<Reverse<QueuedJob>>,
    /// Jobs accepted but not yet finished (queued + running).
    pending: usize,
    /// Jobs currently executing on a worker.
    running: usize,
    /// Virtual time: the largest key handed to a worker so far.  New
    /// unweighted submissions and freshly admitted weighted batches start
    /// here, so nobody can schedule themselves into the already-consumed
    /// past (or starve behind an unbounded future).
    vtime: u64,
    /// Finish tag of the shared "flow" of [`ThreadPool::submit_weighted`]
    /// jobs: each such job starts at `max(vtime, flow_tag)` and advances
    /// the tag by its weight, so a sustained stream of them climbs past
    /// explicitly-keyed batch tails instead of camping at `vtime` and
    /// starving them.
    flow_tag: u64,
    seq: u64,
    closed: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here waiting for jobs.
    work: Condvar,
    /// `wait()` parks here until `pending == 0`.
    idle: Condvar,
}

/// A fixed-size thread pool with a weighted (virtual-time ordered)
/// submission queue.  See the module docs for the scheduling model.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                heap: BinaryHeap::new(),
                pending: 0,
                running: 0,
                vtime: 0,
                flow_tag: 0,
                seq: 0,
                closed: false,
            }),
            work: Condvar::new(),
            idle: Condvar::new(),
        });
        let workers = (0..threads.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                thread::spawn(move || loop {
                    let job = {
                        let mut st = shared.state.lock().unwrap();
                        loop {
                            if let Some(Reverse(qj)) = st.heap.pop() {
                                st.vtime = st.vtime.max(qj.key);
                                st.running += 1;
                                break Some(qj.job);
                            }
                            if st.closed {
                                break None;
                            }
                            st = shared.work.wait(st).unwrap();
                        }
                    };
                    let Some(job) = job else { break };
                    // Contain panics: a panicking job must not kill the
                    // worker or leak the pending count, or the pool (and
                    // the serving scheduler above it) deadlocks with
                    // queued jobs nobody will run.
                    let _ = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(job),
                    );
                    let mut st = shared.state.lock().unwrap();
                    st.running -= 1;
                    st.pending -= 1;
                    if st.pending == 0 {
                        shared.idle.notify_all();
                    }
                })
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Submit a job at the current virtual time (FIFO among plain
    /// submissions).  NOTE: a *sustained* stream of plain jobs camps at
    /// `vtime` and can starve explicitly-keyed batch tails — recurring
    /// job sources should use [`ThreadPool::submit_weighted`] instead.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.push(Key::Now, Box::new(f));
    }

    /// Submit a job that consumes `weight` units of virtual time: it is
    /// enqueued at the shared flow tag (`max(vtime, flow_tag)`), which
    /// then advances by `weight`.  Successive weighted jobs get strictly
    /// increasing keys, so a stream of them interleaves fairly with
    /// explicitly-keyed batches instead of perpetually outranking their
    /// tails.
    pub fn submit_weighted<F: FnOnce() + Send + 'static>(&self, weight: u64, f: F) {
        self.push(Key::Flow(weight), Box::new(f));
    }

    /// Submit a job at an explicit virtual-time `key` (clamped up to the
    /// current virtual time).  Callers spreading a batch of tasks assign
    /// each task `vnow() + cost-prefix-sum`, which interleaves concurrent
    /// batches by cost instead of queueing them back-to-back.
    pub fn submit_at<F: FnOnce() + Send + 'static>(&self, key: u64, f: F) {
        self.push(Key::At(key), Box::new(f));
    }

    /// Enqueue under the state lock.  The pending count and the queue are
    /// updated atomically, and a closed queue (shutdown race) drops the
    /// job *without* counting it — the old two-step
    /// `pending += 1; tx.send().unwrap()` could panic after the increment
    /// and leave `wait()` deadlocked on a job no worker would ever run.
    fn push(&self, key: Key, job: Job) {
        let mut st = self.shared.state.lock().unwrap();
        if st.closed {
            return;
        }
        let key = match key {
            Key::Now => st.vtime,
            Key::At(k) => k.max(st.vtime),
            Key::Flow(weight) => {
                let k = st.flow_tag.max(st.vtime);
                st.flow_tag = k.saturating_add(weight);
                k
            }
        };
        st.seq += 1;
        let seq = st.seq;
        st.pending += 1;
        st.heap.push(Reverse(QueuedJob { key, seq, job }));
        drop(st);
        self.shared.work.notify_one();
    }

    /// Jobs submitted but not yet finished (queued + running) — the
    /// admission signal for the serving scheduler's backpressure.
    pub fn pending(&self) -> usize {
        self.shared.state.lock().unwrap().pending
    }

    /// Jobs waiting in the queue (not yet picked up by a worker).
    pub fn queued(&self) -> usize {
        let st = self.shared.state.lock().unwrap();
        st.pending - st.running
    }

    /// Jobs currently executing on a worker.
    pub fn running(&self) -> usize {
        self.shared.state.lock().unwrap().running
    }

    /// Current virtual time (the largest key a worker has started on).
    pub fn vnow(&self) -> u64 {
        self.shared.state.lock().unwrap().vtime
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Block until every submitted job has finished.
    pub fn wait(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while st.pending > 0 {
            st = self.shared.idle.wait(st).unwrap();
        }
    }

    /// Cooperative fork-join: run `f(i)` for every `i in 0..nparts`
    /// across the *calling thread and* the pool's workers, returning only
    /// when every partition has finished.  The caller always participates
    /// (help-first), so the call makes progress even when every worker is
    /// busy — or when the caller *is* the pool's only worker — with zero
    /// new threads and no deadlock.  Helpers are submitted as
    /// flow-weighted jobs (`weight` = per-partition cost in the pool's
    /// virtual-time currency), so e.g. GEMM partitions interleave fairly
    /// with other pool work instead of jumping the queue.  A panicking
    /// partition is contained until all partitions finish, then re-raised
    /// on the caller.
    pub fn coop_run<F>(&self, nparts: usize, weight: u64, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if nparts <= 1 {
            if nparts == 1 {
                f(0);
            }
            return;
        }
        struct CoopJob {
            /// Next unclaimed partition index; claims past `nparts` are
            /// no-ops (late-waking helpers exit without touching `f`).
            next: AtomicUsize,
            done: Mutex<usize>,
            all_done: Condvar,
            panicked: std::sync::atomic::AtomicBool,
            nparts: usize,
            f: &'static (dyn Fn(usize) + Sync),
        }
        impl CoopJob {
            fn run_some(&self) {
                loop {
                    let i = self.next.fetch_add(1, Ordering::Relaxed);
                    if i >= self.nparts {
                        break;
                    }
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        (self.f)(i)
                    }));
                    if r.is_err() {
                        self.panicked.store(true, Ordering::SeqCst);
                    }
                    let mut done = self.done.lock().unwrap();
                    *done += 1;
                    if *done == self.nparts {
                        self.all_done.notify_all();
                    }
                }
            }
        }
        // SAFETY: lifetime erasure.  The caller blocks below until
        // `done == nparts`, i.e. until every claimed partition has run to
        // completion, so `f` outlives every invocation; a helper that
        // wakes after that claims `i >= nparts` and returns without ever
        // dereferencing `f`.
        let f_static: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(&f)
        };
        let job = Arc::new(CoopJob {
            next: AtomicUsize::new(0),
            done: Mutex::new(0),
            all_done: Condvar::new(),
            panicked: std::sync::atomic::AtomicBool::new(false),
            nparts,
            f: f_static,
        });
        for _ in 0..(nparts - 1).min(self.threads()) {
            let j = Arc::clone(&job);
            self.submit_weighted(weight, move || j.run_some());
        }
        job.run_some();
        let mut done = job.done.lock().unwrap();
        while *done < nparts {
            done = job.all_done.wait(done).unwrap();
        }
        drop(done);
        if job.panicked.load(Ordering::SeqCst) {
            panic!("coop_run partition panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.closed = true;
        }
        self.shared.work.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_map_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread() {
        assert_eq!(parallel_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(1);
        pool.submit(|| panic!("job blew up"));
        pool.wait(); // must not hang: the panic still decrements pending
        let ran = Arc::new(AtomicU64::new(0));
        let r = Arc::clone(&ran);
        pool.submit(move || {
            r.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait();
        assert_eq!(ran.load(Ordering::Relaxed), 1, "worker survived the panic");
    }

    #[test]
    fn pool_wait_idempotent() {
        let pool = ThreadPool::new(2);
        pool.wait();
        pool.submit(|| {});
        pool.wait();
        pool.wait();
    }

    /// Weighted submission is start-time fair queueing: with the single
    /// worker pinned, two queued batches execute strictly by virtual-time
    /// key — a cheap batch admitted later overtakes the expensive tail of
    /// an earlier one instead of waiting for the whole batch.
    #[test]
    fn weighted_batches_interleave_by_virtual_time() {
        let pool = ThreadPool::new(1);
        let gate = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let g = Arc::clone(&gate);
        pool.submit(move || {
            while !g.load(Ordering::SeqCst) {
                thread::sleep(std::time::Duration::from_millis(1));
            }
        });
        // Wait for the worker to pick up the gate job so the batches below
        // are queued (not running) when the gate opens.
        while pool.running() == 0 {
            thread::sleep(std::time::Duration::from_millis(1));
        }
        let order = Arc::new(Mutex::new(Vec::new()));
        // Batch A: one huge layer then cost-100 tail; batch B: three cheap
        // layers.  Keys are vnow() + cost prefix sums per batch.
        for (tag, key) in
            [("a0", 0u64), ("a1", 1000), ("b0", 0), ("b1", 10), ("b2", 20)]
        {
            let order = Arc::clone(&order);
            pool.submit_at(key, move || {
                order.lock().unwrap().push(tag);
            });
        }
        gate.store(true, Ordering::SeqCst);
        pool.wait();
        assert_eq!(
            *order.lock().unwrap(),
            vec!["a0", "b0", "b1", "b2", "a1"],
            "cheap batch B overtakes batch A's expensive tail"
        );
    }

    /// A stream of flow-weighted jobs cannot starve an explicitly-keyed
    /// batch tail: each flow job advances the shared tag by its weight,
    /// so the tail (key = 3 weights ahead) runs after exactly three of
    /// them, not after all ten.
    #[test]
    fn weighted_flow_cannot_starve_batch_tails() {
        let pool = ThreadPool::new(1);
        let gate = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let g = Arc::clone(&gate);
        pool.submit(move || {
            while !g.load(Ordering::SeqCst) {
                thread::sleep(std::time::Duration::from_millis(1));
            }
        });
        while pool.running() == 0 {
            thread::sleep(std::time::Duration::from_millis(1));
        }
        const W: u64 = 100;
        let order = Arc::new(Mutex::new(Vec::new()));
        let o = Arc::clone(&order);
        pool.submit_at(3 * W, move || o.lock().unwrap().push(usize::MAX));
        for i in 0..10usize {
            let o = Arc::clone(&order);
            pool.submit_weighted(W, move || o.lock().unwrap().push(i));
        }
        gate.store(true, Ordering::SeqCst);
        pool.wait();
        let order = order.lock().unwrap();
        let tail_pos = order.iter().position(|&x| x == usize::MAX).unwrap();
        assert_eq!(
            tail_pos, 3,
            "tail ran after 3 flow jobs (flow keys 0,100,200 then tie at \
             300 broken by seq), got order {order:?}"
        );
    }

    /// Regression (shutdown race): submitting after the queue closed must
    /// drop the job without counting it — the old implementation bumped
    /// `pending` first and panicked on the dead channel, leaving `wait()`
    /// deadlocked.
    #[test]
    fn submit_after_close_neither_panics_nor_leaks_pending() {
        let pool = ThreadPool::new(1);
        pool.shared.state.lock().unwrap().closed = true;
        pool.shared.work.notify_all();
        pool.submit(|| panic!("must never run"));
        assert_eq!(pool.pending(), 0, "dropped job was not counted");
        pool.wait(); // must return immediately, not deadlock
    }

    #[test]
    fn coop_run_covers_every_partition_exactly_once() {
        let pool = ThreadPool::new(3);
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        pool.coop_run(hits.len(), 10, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "partition {i}");
        }
    }

    #[test]
    fn coop_run_zero_and_one_partitions_run_inline() {
        let pool = ThreadPool::new(2);
        let n = AtomicU64::new(0);
        pool.coop_run(0, 1, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 0);
        pool.coop_run(1, 1, |i| {
            assert_eq!(i, 0);
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 1);
        assert_eq!(pool.pending(), 0, "single partition never touches the queue");
    }

    /// The caller makes progress even when every worker is pinned on
    /// other jobs: help-first means a saturated pool degrades to inline
    /// execution instead of deadlocking.
    #[test]
    fn coop_run_progresses_with_all_workers_busy() {
        let pool = ThreadPool::new(1);
        let gate = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let g = Arc::clone(&gate);
        pool.submit(move || {
            while !g.load(Ordering::SeqCst) {
                thread::sleep(std::time::Duration::from_millis(1));
            }
        });
        while pool.running() == 0 {
            thread::sleep(std::time::Duration::from_millis(1));
        }
        let n = AtomicU64::new(0);
        pool.coop_run(8, 5, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8, "caller drained all partitions");
        gate.store(true, Ordering::SeqCst);
        pool.wait();
    }

    #[test]
    fn coop_run_repanics_on_caller_after_all_partitions_finish() {
        let pool = ThreadPool::new(2);
        let ran = Arc::new(AtomicU64::new(0));
        let r = Arc::clone(&ran);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.coop_run(6, 1, |i| {
                r.fetch_add(1, Ordering::Relaxed);
                if i == 2 {
                    panic!("partition 2 blew up");
                }
            });
        }));
        assert!(res.is_err(), "partition panic reaches the caller");
        assert_eq!(ran.load(Ordering::Relaxed), 6, "other partitions still ran");
        // The pool stays usable afterwards.
        let n = AtomicU64::new(0);
        pool.coop_run(4, 1, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn pool_gauges_track_queue_and_running() {
        let pool = ThreadPool::new(1);
        let release = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let r = Arc::clone(&release);
        pool.submit(move || {
            while !r.load(Ordering::SeqCst) {
                thread::sleep(std::time::Duration::from_millis(1));
            }
        });
        while pool.running() != 1 {
            thread::sleep(std::time::Duration::from_millis(1));
        }
        pool.submit(|| {});
        assert_eq!(pool.pending(), 2);
        assert_eq!(pool.queued(), 1);
        release.store(true, Ordering::SeqCst);
        pool.wait();
        assert_eq!(pool.pending(), 0);
        assert_eq!(pool.queued(), 0);
        assert_eq!(pool.running(), 0);
    }
}
