//! Parallel-execution helpers (no rayon in the offline vendor set).
//!
//! Two primitives:
//!  * [`parallel_for`] — scoped fork-join over an index range, used by the
//!    coordinator to quantize layers/channels concurrently;
//!  * [`ThreadPool`] — a persistent pool with a submission queue, used by the
//!    long-lived on-the-fly service.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Run `f(i)` for every `i in 0..n`, work-stealing via an atomic counter.
/// `f` may produce a value; results are returned in index order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return (0..n).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let out_ptr = SendPtr(out.as_mut_ptr());
    thread::scope(|s| {
        for _ in 0..threads {
            let fref = &f;
            let nref = &next;
            let optr = &out_ptr;
            s.spawn(move || loop {
                let i = nref.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = fref(i);
                // SAFETY: each index i is claimed exactly once, slots are
                // disjoint, and the scope outlives all writes.
                unsafe {
                    *optr.0.add(i) = Some(v);
                }
            });
        }
    });
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// Run `f(i)` for side effects only.
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    parallel_map(n, threads, |i| f(i));
}

struct SendPtr<T>(*mut T);
// SAFETY: used only with disjoint index writes inside a scope.
unsafe impl<T> Sync for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}

// ---------------------------------------------------------------------------
// Persistent pool
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size thread pool with a shared FIFO queue.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let workers = (0..threads.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            // Contain panics: a panicking job must not kill
                            // the worker or leak the pending count, or the
                            // pool (and the serving scheduler above it)
                            // deadlocks with queued jobs nobody will run.
                            let _ = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(job),
                            );
                            let (lock, cv) = &*pending;
                            let mut cnt = lock.lock().unwrap();
                            *cnt -= 1;
                            if *cnt == 0 {
                                cv.notify_all();
                            }
                        }
                        Err(_) => break,
                    }
                })
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Submit a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx.as_ref().unwrap().send(Box::new(f)).unwrap();
    }

    /// Jobs submitted but not yet finished (queued + running) — the
    /// admission signal for the serving scheduler's backpressure.
    pub fn pending(&self) -> usize {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap()
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Block until every submitted job has finished.
    pub fn wait(&self) {
        let (lock, cv) = &*self.pending;
        let mut cnt = lock.lock().unwrap();
        while *cnt > 0 {
            cnt = cv.wait(cnt).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close queue
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_map_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_thread() {
        assert_eq!(parallel_map(5, 1, |i| i + 1), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(1);
        pool.submit(|| panic!("job blew up"));
        pool.wait(); // must not hang: the panic still decrements pending
        let ran = Arc::new(AtomicU64::new(0));
        let r = Arc::clone(&ran);
        pool.submit(move || {
            r.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait();
        assert_eq!(ran.load(Ordering::Relaxed), 1, "worker survived the panic");
    }

    #[test]
    fn pool_wait_idempotent() {
        let pool = ThreadPool::new(2);
        pool.wait();
        pool.submit(|| {});
        pool.wait();
        pool.wait();
    }
}
