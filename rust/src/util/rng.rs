//! Deterministic RNG: xoshiro256** (public-domain algorithm by
//! Blackman & Vigna), plus the distribution helpers the crate needs.
//!
//! Every stochastic component (synthetic calibration data, property tests,
//! workload generators) takes an explicit [`Rng`] so runs are reproducible
//! from the CLI `--seed`.

/// xoshiro256** state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via splitmix64 (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let mut u1 = self.f32();
        if u1 < 1e-12 {
            u1 = 1e-12;
        }
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Fill a slice with N(0, std).
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent stream (for per-thread RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
            / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
