//! Minimal leveled, structured logger for the serving stack.
//!
//! One process-global sink writing single lines to stderr — stdout is
//! reserved for protocol use (the worker ready-line, bench snapshots).
//! Two render modes share one call site API:
//!
//!  * text (default): `1723112345.123 WARN shard_down shard=2 reason=...`
//!  * JSON (`--log-json`): `{"ts":...,"level":"warn","event":"shard_down",
//!    "shard":2,...}` — one valid JSON document per line, so log shippers
//!    and the bench-serve `--trace --strict` assertions can parse every
//!    line without a grammar.
//!
//! The level and mode live in atomics so `init` is race-free and callers
//! never take a lock to discover that a `debug` line is filtered out.
//! There is deliberately no macro layer: an event name plus a small
//! `(&str, Json)` field slice covers everything the serving paths emit.

use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::Json;

/// Severity, ordered so a numeric comparison implements filtering.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s {
            "debug" => Some(Level::Debug),
            "info" => Some(Level::Info),
            "warn" => Some(Level::Warn),
            "error" => Some(Level::Error),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Level::Debug => "debug",
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }
}

static MIN_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static JSON_MODE: AtomicBool = AtomicBool::new(false);

/// Configure the process logger. Safe to call more than once (last call
/// wins); callers that never init get text mode at `info`.
pub fn init(level: Level, json: bool) {
    MIN_LEVEL.store(level as u8, Ordering::Relaxed);
    JSON_MODE.store(json, Ordering::Relaxed);
}

/// Would a line at `level` be emitted? Lets callers skip building
/// expensive field sets for filtered levels.
pub fn enabled(level: Level) -> bool {
    level as u8 >= MIN_LEVEL.load(Ordering::Relaxed)
}

fn now_ts() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// Render one event as a single line (no trailing newline) in the
/// process-global mode; `log` is the emitting entry point.
pub fn render(level: Level, event: &str, fields: &[(&str, Json)]) -> String {
    render_with(JSON_MODE.load(Ordering::Relaxed), level, event, fields)
}

/// Mode-explicit renderer (tests use this to avoid racing on the global
/// mode flag; the two modes must stay line-for-line equivalent in content).
pub fn render_with(
    json: bool,
    level: Level,
    event: &str,
    fields: &[(&str, Json)],
) -> String {
    if json {
        let mut doc = Json::obj()
            .set("ts", now_ts())
            .set("level", level.as_str())
            .set("event", event);
        for (k, v) in fields {
            doc = doc.set(k, v.clone());
        }
        doc.dump()
    } else {
        let mut line = format!("{:.3} {} {}", now_ts(), level.as_str(), event);
        for (k, v) in fields {
            let val = match v {
                Json::Str(s) => s.clone(),
                other => other.dump(),
            };
            line.push_str(&format!(" {k}={val}"));
        }
        line
    }
}

/// Emit one structured line to stderr if `level` passes the filter.
pub fn log(level: Level, event: &str, fields: &[(&str, Json)]) {
    if !enabled(level) {
        return;
    }
    let line = render(level, event, fields);
    let stderr = std::io::stderr();
    let mut out = stderr.lock();
    let _ = writeln!(out, "{line}");
}

pub fn debug(event: &str, fields: &[(&str, Json)]) {
    log(Level::Debug, event, fields);
}

pub fn info(event: &str, fields: &[(&str, Json)]) {
    log(Level::Info, event, fields);
}

pub fn warn(event: &str, fields: &[(&str, Json)]) {
    log(Level::Warn, event, fields);
}

pub fn error(event: &str, fields: &[(&str, Json)]) {
    log(Level::Error, event, fields);
}

/// Install a panic hook that logs one structured `panic` event (with the
/// worker's shard id when given) before chaining to the previous hook —
/// so a router reading a dead worker's stderr can explain the respawn.
pub fn install_panic_hook(shard: Option<usize>) {
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let msg = if let Some(s) = info.payload().downcast_ref::<&str>() {
            (*s).to_string()
        } else if let Some(s) = info.payload().downcast_ref::<String>() {
            s.clone()
        } else {
            "non-string panic payload".to_string()
        };
        let mut fields: Vec<(&str, Json)> = vec![("message", Json::from(msg))];
        if let Some(s) = shard {
            fields.push(("shard", Json::from(s)));
        }
        let loc = info.location().map(|l| format!("{}:{}", l.file(), l.line()));
        if let Some(l) = loc {
            fields.push(("location", Json::from(l)));
        }
        log(Level::Error, "panic", &fields);
        prev(info);
    }));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_round_trips() {
        for l in [Level::Debug, Level::Info, Level::Warn, Level::Error] {
            assert_eq!(Level::parse(l.as_str()), Some(l));
        }
        assert_eq!(Level::parse("loud"), None);
        assert!(Level::Debug < Level::Error);
    }

    #[test]
    fn json_render_parses_and_carries_fields() {
        let line = render_with(
            true,
            Level::Warn,
            "shard_down",
            &[("shard", Json::from(2usize)), ("reason", Json::from("io"))],
        );
        let doc = Json::parse(&line).expect("log line is one JSON doc");
        assert_eq!(doc.req("level").unwrap().as_str().unwrap(), "warn");
        assert_eq!(doc.req("event").unwrap().as_str().unwrap(), "shard_down");
        assert_eq!(doc.req("shard").unwrap().as_usize().unwrap(), 2);
        assert!(doc.req("ts").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn text_render_is_single_line_key_values() {
        let line = render_with(
            false,
            Level::Info,
            "respawn",
            &[("shard", Json::from(1usize))],
        );
        assert!(line.contains("info respawn"), "{line}");
        assert!(line.contains("shard=1"), "{line}");
        assert!(!line.contains('\n'));
    }
}
