//! Hand-rolled benchmark harness (criterion is not in the offline vendor
//! set).  Used by every `[[bench]]` target with `harness = false`.
//!
//! Method: warmup runs, then N timed repetitions; reports min / median /
//! mean / p95 so the paper tables can cite medians (robust against CI
//! noise).  Deliberately simple — the paper's timing claims are order-of-
//! magnitude claims (ms vs s vs h), not microsecond-level ones.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub reps: usize,
    pub min_ns: u128,
    pub median_ns: u128,
    pub mean_ns: u128,
    pub p95_ns: u128,
}

impl Stats {
    pub fn median_ms(&self) -> f64 {
        self.median_ns as f64 / 1e6
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns as f64 / 1e6
    }
}

fn fmt_ns(ns: u128) -> String {
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

impl std::fmt::Display for Stats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} reps={:<4} min={:>10} median={:>10} mean={:>10} p95={:>10}",
            self.name,
            self.reps,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns)
        )
    }
}

/// Time `f` with `warmup` untimed and `reps` timed repetitions.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, reps: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<u128> = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos());
    }
    samples.sort_unstable();
    let n = samples.len();
    Stats {
        name: name.to_string(),
        reps: n,
        min_ns: samples[0],
        median_ns: samples[n / 2],
        mean_ns: samples.iter().sum::<u128>() / n as u128,
        p95_ns: samples[((n as f64 * 0.95) as usize).min(n - 1)],
    }
}

/// Time a single run of `f`, returning (result, millis).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64() * 1e3)
}

/// Print a markdown-style table row (used by bench binaries for
/// paper-table-shaped output).
pub fn table_row(cols: &[&str], widths: &[usize]) -> String {
    let mut s = String::from("|");
    for (c, w) in cols.iter().zip(widths) {
        s.push_str(&format!(" {c:<w$} |", w = w));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_reps() {
        let mut n = 0;
        let st = bench("x", 2, 10, || n += 1);
        assert_eq!(n, 12);
        assert_eq!(st.reps, 10);
        assert!(st.min_ns <= st.median_ns && st.median_ns <= st.p95_ns);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, ms) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(ms >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_ns(500).contains("ns"));
        assert!(fmt_ns(5_000).contains("µs"));
        assert!(fmt_ns(5_000_000).contains("ms"));
        assert!(fmt_ns(5_000_000_000).contains("s"));
    }
}
