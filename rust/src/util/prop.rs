//! Mini property-testing helper (proptest is not in the offline vendor set).
//!
//! [`forall`] runs a property over `n` seeded cases; on failure it retries
//! with a binary-search-style "shrink" over the case's size hint and reports
//! the smallest failing seed.  Used by the SQuant invariant suites
//! (`rust/tests/`) the way the paper's Eq. 9-12 post-conditions demand.

use crate::util::rng::Rng;

/// A generated test case: the RNG to draw from plus a size in [1, max_size].
pub struct Case {
    pub rng: Rng,
    pub size: usize,
}

/// Run `prop` over `n` cases derived from `seed`.  `prop` returns
/// `Err(reason)` to signal failure.  Panics with the seed + smallest size
/// that still fails, so failures are reproducible.
pub fn forall<F>(name: &str, seed: u64, n: usize, max_size: usize, prop: F)
where
    F: Fn(&mut Case) -> Result<(), String>,
{
    let mut meta = Rng::new(seed);
    for i in 0..n {
        let case_seed = meta.next_u64();
        let size = 1 + (meta.below(max_size.max(1)));
        let mut case = Case { rng: Rng::new(case_seed), size };
        if let Err(msg) = prop(&mut case) {
            // Shrink: halve the size while it still fails.
            let mut best = (size, msg);
            let mut s = size / 2;
            while s >= 1 {
                let mut c = Case { rng: Rng::new(case_seed), size: s };
                match prop(&mut c) {
                    Err(m) => {
                        best = (s, m);
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {i}, seed {case_seed}, \
                 shrunk size {}): {}",
                best.0, best.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially_true() {
        forall("true", 1, 50, 10, |_c| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn fails_trivially_false() {
        forall("always-false", 1, 5, 10, |_c| Err("nope".into()));
    }

    #[test]
    fn sizes_in_range() {
        forall("size-range", 2, 100, 7, |c| {
            if (1..=7).contains(&c.size) {
                Ok(())
            } else {
                Err(format!("size {}", c.size))
            }
        });
    }
}
