//! Self-contained utility layer.
//!
//! The offline vendor set ships only `xla` + `anyhow`, so the crate carries
//! its own JSON codec, RNG, thread pool, CLI parser, bench harness,
//! structured logger and a small property-testing helper — all
//! deliberately minimal but real (tested in each module).

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod pool;
pub mod prop;
pub mod rng;

/// Round-half-up, the crate-wide rounding convention (matches
/// `python/compile/common.py::rn` bit-for-bit so the native and AOT SQuant
/// paths agree on .5 grid points).
#[inline(always)]
pub fn rn(x: f32) -> f32 {
    (x + 0.5).floor()
}

/// sign with sign(0) = 0 (shared semantic decision, see kernels/ref.py).
#[inline(always)]
pub fn sign(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

/// FNV-1a 64-bit hash — the crate-wide stable key hash (spec key hashes,
/// artifact file names, model fingerprints).  Deliberately not `DefaultHasher`:
/// the value is persisted on disk, so it must be stable across Rust versions.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(bytes);
    h.finish()
}

/// Incremental form of [`fnv1a`], for hashing streams (e.g. model files read
/// in chunks) without buffering them whole.  Feeding the same bytes in any
/// chunking produces the same hash as the one-shot function.
pub struct Fnv1a {
    h: u64,
}

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a { h: 0xcbf2_9ce4_8422_2325 }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.h = (self.h ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    pub fn finish(&self) -> u64 {
        self.h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rn_half_up() {
        assert_eq!(rn(0.5), 1.0);
        assert_eq!(rn(-0.5), 0.0);
        assert_eq!(rn(1.5), 2.0);
        assert_eq!(rn(2.4), 2.0);
        assert_eq!(rn(-1.6), -2.0);
        assert_eq!(rn(0.0), 0.0);
    }

    #[test]
    fn sign_zero() {
        assert_eq!(sign(0.0), 0.0);
        assert_eq!(sign(1e-30), 1.0);
        assert_eq!(sign(-1e-30), -1.0);
    }

    #[test]
    fn fnv1a_incremental_matches_one_shot() {
        let data = b"squant artifact fingerprint";
        let mut h = Fnv1a::new();
        h.update(&data[..7]);
        h.update(&data[7..]);
        assert_eq!(h.finish(), fnv1a(data));
        assert_eq!(Fnv1a::new().finish(), fnv1a(b""));
    }
}
