//! Self-contained utility layer.
//!
//! The offline vendor set ships only `xla` + `anyhow`, so the crate carries
//! its own JSON codec, RNG, thread pool, CLI parser, bench harness and a
//! small property-testing helper — all deliberately minimal but real
//! (tested in each module).

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;

/// Round-half-up, the crate-wide rounding convention (matches
/// `python/compile/common.py::rn` bit-for-bit so the native and AOT SQuant
/// paths agree on .5 grid points).
#[inline(always)]
pub fn rn(x: f32) -> f32 {
    (x + 0.5).floor()
}

/// sign with sign(0) = 0 (shared semantic decision, see kernels/ref.py).
#[inline(always)]
pub fn sign(x: f32) -> f32 {
    if x > 0.0 {
        1.0
    } else if x < 0.0 {
        -1.0
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rn_half_up() {
        assert_eq!(rn(0.5), 1.0);
        assert_eq!(rn(-0.5), 0.0);
        assert_eq!(rn(1.5), 2.0);
        assert_eq!(rn(2.4), 2.0);
        assert_eq!(rn(-1.6), -2.0);
        assert_eq!(rn(0.0), 0.0);
    }

    #[test]
    fn sign_zero() {
        assert_eq!(sign(0.0), 0.0);
        assert_eq!(sign(1e-30), 1.0);
        assert_eq!(sign(-1e-30), -1.0);
    }
}
