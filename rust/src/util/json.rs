//! Minimal JSON codec (the vendored crate set has no serde).
//!
//! Supports the full JSON grammar the pipeline emits: objects, arrays,
//! strings (with \uXXXX escapes), f64 numbers, bools, null.  Object key
//! order is preserved on write (Vec-backed map) so reports diff cleanly.

use anyhow::{anyhow, bail, Result};
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---- constructors -----------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Set `key` on an object: replaces an existing entry in place (keeping
    /// its position) or appends a new one, so rebuilding a parsed header
    /// never produces duplicate keys.
    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(ref mut kv) = self {
            let val = val.into();
            if let Some(slot) = kv.iter_mut().find(|(k, _)| k == key) {
                slot.1 = val;
            } else {
                kv.push((key.to_string(), val));
            }
        }
        self
    }

    // ---- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(kv) => Ok(kv),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }

    // ---- codec -------------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            bail!("trailing garbage at byte {pos}");
        }
        Ok(v)
    }

    pub fn dump(&self) -> String {
        let mut s = String::new();
        write_value(self, &mut s);
        s
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    if b.len() - *pos >= lit.len() && &b[*pos..*pos + lit.len()] == lit.as_bytes() {
        *pos += lit.len();
        Ok(v)
    } else {
        bail!("bad literal at byte {}", *pos)
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if *pos >= b.len() || b[*pos] != b'"' {
        bail!("expected string at byte {}", *pos);
    }
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    bail!("truncated escape");
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            bail!("truncated \\u escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let cp = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => bail!("bad escape \\{}", c as char),
                }
                *pos += 1;
            }
            _ => {
                // Copy a UTF-8 run verbatim.
                let start = *pos;
                while *pos < b.len() && b[*pos] != b'"' && b[*pos] != b'\\' {
                    *pos += 1;
                }
                out.push_str(std::str::from_utf8(&b[start..*pos])?);
            }
        }
    }
    bail!("unterminated string")
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '['
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            _ => bail!("expected ',' or ']' at byte {}", *pos),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            bail!("expected ':' at byte {}", *pos);
        }
        *pos += 1;
        let val = parse_value(b, pos)?;
        out.push((key, val));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            _ => bail!("expected ',' or '}}' at byte {}", *pos),
        }
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

fn write_value(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                let _ = write!(out, "{}", *x as i64);
            } else {
                let _ = write!(out, "{x}");
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_value(x, out);
            }
            out.push(']');
        }
        Json::Obj(kv) => {
            out.push('{');
            for (i, (k, x)) in kv.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_value(x, out);
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic() {
        let j = Json::parse(r#"{"a": 1, "b": [true, null, "x\n"], "c": -2.5e1}"#)
            .unwrap();
        assert_eq!(j.req("a").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.req("c").unwrap().as_f64().unwrap(), -25.0);
        let arr = j.req("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Bool(true));
        assert_eq!(arr[2].as_str().unwrap(), "x\n");
    }

    #[test]
    fn round_trip() {
        let src = r#"{"name":"m","shape":[1,2,3],"meta":{"acc":0.875},"f":false}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("123 45").is_err());
    }

    #[test]
    fn builder() {
        let j = Json::obj().set("x", 3usize).set("y", "s");
        assert_eq!(j.dump(), r#"{"x":3,"y":"s"}"#);
    }

    #[test]
    fn set_replaces_existing_key_in_place() {
        let j = Json::obj().set("x", 1usize).set("y", 2usize).set("x", 9usize);
        assert_eq!(j.dump(), r#"{"x":9,"y":2}"#);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::Num(3.0).dump(), "3");
        assert_eq!(Json::Num(3.5).dump(), "3.5");
    }
}
