//! Adversarial/corrupted SQNT containers: every malformed input must come
//! back as a clean `Err`, never a panic or a silently-corrupted tensor.
//! The disk cache tier feeds artifact files straight into this codec, and
//! a cache directory is ordinary mutable filesystem state — so the decoder
//! is a trust boundary.

use squant::io::sqnt;
use std::path::PathBuf;

/// Assemble raw container bytes: magic | version | header_len | header |
/// f32le payload.
fn container(version: u32, header: &str, floats: &[f32]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(b"SQNT");
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&(header.len() as u32).to_le_bytes());
    out.extend_from_slice(header.as_bytes());
    for v in floats {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn write_case(tag: &str, bytes: &[u8]) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("sqnt_adversarial_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{tag}.sqnt"));
    std::fs::write(&path, bytes).unwrap();
    path
}

/// Load must error (not panic); the message should mention `needle` so the
/// operator can tell which validation fired.
fn assert_rejected(tag: &str, bytes: &[u8], needle: &str) {
    let path = write_case(tag, bytes);
    let err = match sqnt::load(&path) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("{tag}: load unexpectedly succeeded"),
    };
    assert!(
        err.to_lowercase().contains(needle),
        "{tag}: error {err:?} should mention {needle:?}"
    );
}

fn header_with_table(table: &str) -> String {
    format!(r#"{{"name":"t","tensors":[{table}]}}"#)
}

#[test]
fn truncated_payload_is_an_error() {
    let h = header_with_table(r#"{"name":"w","shape":[6],"offset":0,"numel":6}"#);
    // Declares 6 floats, ships 4.
    assert_rejected(
        "truncated_payload",
        &container(1, &h, &[1., 2., 3., 4.]),
        "exceeds payload",
    );
}

#[test]
fn offset_past_end_is_an_error() {
    let h = header_with_table(r#"{"name":"w","shape":[2],"offset":1000,"numel":2}"#);
    assert_rejected(
        "offset_past_end",
        &container(1, &h, &[0.0; 4]),
        "exceeds payload",
    );
}

#[test]
fn overlapping_offsets_are_an_error() {
    let h = header_with_table(
        r#"{"name":"a","shape":[4],"offset":0,"numel":4},
           {"name":"b","shape":[4],"offset":2,"numel":4}"#,
    );
    assert_rejected(
        "overlapping_offsets",
        &container(1, &h, &[0.0; 6]),
        "overlap",
    );
}

#[test]
fn oversized_header_is_an_error() {
    // header_len claims almost 4 GiB in a 40-byte file; the old unchecked
    // `pos + hlen` could wrap instead of failing.
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"SQNT");
    bytes.extend_from_slice(&1u32.to_le_bytes());
    bytes.extend_from_slice(&u32::MAX.to_le_bytes());
    bytes.extend_from_slice(&[b'{'; 28]);
    assert_rejected("oversized_header", &bytes, "truncated header");
}

#[test]
fn huge_offset_overflow_is_an_error() {
    // offset saturates to usize::MAX through the JSON f64 path; the old
    // `payload_start + 4 * offset` arithmetic overflowed and panicked.
    let h = header_with_table(
        r#"{"name":"w","shape":[4],"offset":1e300,"numel":4}"#,
    );
    assert_rejected(
        "huge_offset",
        &container(1, &h, &[0.0; 4]),
        "exceeds payload",
    );
}

#[test]
fn shape_product_overflow_is_an_error() {
    let h = header_with_table(
        r#"{"name":"w","shape":[100000000000,100000000000],"offset":0,"numel":4}"#,
    );
    assert_rejected(
        "shape_overflow",
        &container(1, &h, &[0.0; 4]),
        "overflow",
    );
}

#[test]
fn numel_shape_mismatch_is_an_error() {
    let h = header_with_table(r#"{"name":"w","shape":[2,2],"offset":0,"numel":5}"#);
    assert_rejected(
        "numel_mismatch",
        &container(1, &h, &[0.0; 5]),
        "numel",
    );
}

#[test]
fn wrong_version_and_magic_are_errors() {
    let h = header_with_table(r#"{"name":"w","shape":[1],"offset":0,"numel":1}"#);
    assert_rejected("wrong_version", &container(9, &h, &[0.0]), "version");
    let mut bad_magic = container(1, &h, &[0.0]);
    bad_magic[0..4].copy_from_slice(b"NOPE");
    assert_rejected("bad_magic", &bad_magic, "not a sqnt container");
}

#[test]
fn valid_gapped_payload_still_loads() {
    // Gaps (non-contiguous but in-bounds, non-overlapping) are legal on
    // load — only writes require a gap-free permutation.
    let h = header_with_table(
        r#"{"name":"a","shape":[2],"offset":4,"numel":2},
           {"name":"b","shape":[2],"offset":0,"numel":2}"#,
    );
    let path = write_case(
        "gapped_ok",
        &container(1, &h, &[9., 8., 0., 0., 1., 2.]),
    );
    let c = sqnt::load(&path).unwrap();
    assert_eq!(c.params["a"].data, vec![1., 2.]);
    assert_eq!(c.params["b"].data, vec![9., 8.]);
}
