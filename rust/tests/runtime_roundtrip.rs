//! PJRT runtime integration tests — require `make artifacts`.
//!
//! The key cross-validation of the whole stack: the AOT JAX/Pallas SQuant
//! HLO (validated against the numpy oracle in pytest) must agree with the
//! native Rust implementation on the integer grid assignment.

use squant::eval::tables::Env;
use squant::io::sqnt;
use squant::nn::engine::forward;
use squant::nn::Graph;
use squant::quant::{channel_scales, QuantConfig};
use squant::runtime::Runtime;
use squant::squant::{squant, SquantOpts};
use squant::tensor::Tensor;
use squant::util::rng::Rng;

fn env() -> Env {
    Env::load("artifacts").expect("run `make artifacts` first")
}

#[test]
fn squant_hlo_bitexact_vs_native() {
    let env = env();
    let rt = Runtime::cpu().unwrap();
    let mut rng = Rng::new(0xC0FFEE);
    let mut tested = 0;
    let mut shapes: Vec<_> = env.man.squant.iter().collect();
    shapes.sort_by_key(|(s, _)| (s.m, s.n, s.k, s.bits));
    for (shape, path) in shapes {
        // Keep runtime bounded: every distinct (n, k) at both bit widths.
        if tested >= 12 {
            break;
        }
        let mut w = Tensor::zeros(&[shape.m, shape.n, shape.k]);
        rng.fill_normal(&mut w.data, 0.1);
        let w4 = Tensor::from_vec(&[shape.m, shape.n, 1, shape.k],
                                  w.data.clone());
        let scales = channel_scales(&w4, QuantConfig::new(shape.bits));
        let s = Tensor::from_vec(&[shape.m], scales.clone());

        let outs = rt.run(path, &[&w, &s]).expect("offload failed");
        let native = squant(&w4, &scales, SquantOpts::full(shape.bits));

        assert_eq!(outs[0].data, native.q.data,
                   "q mismatch for {shape:?}");
        for (a, b) in outs[1].data.iter().zip(&native.wq.data) {
            assert!((a - b).abs() < 1e-6, "wq mismatch for {shape:?}");
        }
        tested += 1;
    }
    assert!(tested >= 4, "too few squant artifacts found");
}

#[test]
fn forward_hlo_matches_native_engine() {
    let env = env();
    let entry = env.man.model("miniresnet18").unwrap();
    let c = sqnt::load(&entry.sqnt).unwrap();
    let graph = Graph::from_header(&c.header).unwrap();
    let rt = Runtime::cpu().unwrap();
    let path = entry.forward.get(&1).expect("b1 forward artifact");
    let exe = rt.load(path).unwrap();

    let (x, _) = env.test.batch(3, 1);
    let native = forward(&graph, &c.params, &x, None, None).unwrap().logits;

    let mut inputs: Vec<&Tensor> = vec![&x];
    let ordered: Vec<&Tensor> = c.order.iter().map(|n| &c.params[n]).collect();
    inputs.extend(ordered.iter());
    let outs = rt.execute(&exe, &inputs).unwrap();

    assert_eq!(outs[0].shape, native.shape);
    for (a, b) in outs[0].data.iter().zip(&native.data) {
        assert!((a - b).abs() < 2e-3,
                "logit mismatch: pjrt {a} vs native {b}");
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    let env = env();
    let rt = Runtime::cpu().unwrap();
    let (_, path) = env.man.squant.iter().next().unwrap();
    let _ = rt.load(path).unwrap();
    let n1 = rt.cached_executables();
    let _ = rt.load(path).unwrap();
    assert_eq!(rt.cached_executables(), n1);
}
