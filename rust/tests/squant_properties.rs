//! Property-based test suite for the SQuant core (no artifacts needed).
//!
//! Uses the in-crate `util::prop` harness (seeded, shrinking) to sweep
//! random shapes / bit widths / weight scales and assert the paper's
//! Eq. 9-12 post-conditions plus algebraic properties of the algorithm.

use squant::quant::{channel_scales, perturbation, quantize_rtn, QuantConfig};
use squant::squant::{case_objective, check_invariants, squant, squant_auto,
                     squant_traced, SquantOpts};
use squant::tensor::Tensor;
use squant::util::prop::{forall, Case};

fn rand_weight(c: &mut Case, k_choices: &[usize]) -> (Tensor, usize) {
    let m = 1 + c.rng.below(c.size.max(1));
    let n = 1 + c.rng.below(c.size.max(1));
    let k = k_choices[c.rng.below(k_choices.len())];
    let std = [0.01f32, 0.1, 1.0][c.rng.below(3)];
    let shape = if k == 1 { vec![m, n] } else { vec![m, n, 1, k] };
    let mut w = Tensor::zeros(&shape);
    let mut data = vec![0.0f32; w.numel()];
    c.rng.fill_normal(&mut data, std);
    w.data = data;
    (w, k)
}

#[test]
fn invariants_hold_for_all_shapes_and_bits() {
    forall("squant-invariants", 0xA11CE, 120, 8, |c| {
        let (w, _) = rand_weight(c, &[1, 3, 9, 25]);
        let bits = [3usize, 4, 6, 8][c.rng.below(4)];
        let opts = SquantOpts::full(bits);
        let res = squant_auto(&w, bits);
        check_invariants(&w, &res, opts)
            .map(|_| ())
            .map_err(|e| format!("{e} ({:?})", w.shape))
    });
}

#[test]
fn ablation_variants_hold_their_bounds() {
    forall("squant-ablation-invariants", 0xB0B, 80, 6, |c| {
        let (w, _) = rand_weight(c, &[3, 9]);
        let bits = [3usize, 4][c.rng.below(2)];
        let scales = channel_scales(&w, QuantConfig::new(bits));
        for opts in [SquantOpts::ek(bits), SquantOpts::ec(bits)] {
            let res = squant(&w, &scales, opts);
            check_invariants(&w, &res, opts)
                .map(|_| ())
                .map_err(|e| format!("{} {e}", opts.label()))?;
        }
        Ok(())
    });
}

#[test]
fn case_objective_improves_in_aggregate() {
    // The progressive algorithm enforces the *constraints* (|kernel ASE|
    // and |channel ASE| bounds — covered by the invariant tests); strict
    // per-instance descent of the summed Eq. (8) objective is NOT
    // guaranteed (a flip can trade +0.1 element error for -0.02 kernel
    // error when the kernel ASE sits just above 0.5).  What must hold is
    // aggregate improvement over random tensors — and by a wide margin.
    let mut o_sq_total = 0.0f64;
    let mut o_rtn_total = 0.0f64;
    let mut wins = 0usize;
    let mut cases = 0usize;
    forall("case-objective-aggregate", 0xCAFE, 100, 8, |c| {
        let (w, _) = rand_weight(c, &[1, 3, 9]);
        let bits = [3usize, 4, 8][c.rng.below(3)];
        let scales = channel_scales(&w, QuantConfig::new(bits));
        let res = squant(&w, &scales, SquantOpts::full(bits));
        let q_rtn = quantize_rtn(&w, &scales, bits);
        let o_sq = case_objective(&perturbation(&w, &res.q, &scales)) as f64;
        let o_rtn = case_objective(&perturbation(&w, &q_rtn, &scales)) as f64;
        // (captured via raw pointers is overkill; use thread_local-free
        // accumulation through a RefCell-like trick instead: forall runs
        // sequentially, so unsafe-free accumulation via a mutex is fine.)
        ACC.with(|a| {
            let mut a = a.borrow_mut();
            a.0 += o_sq;
            a.1 += o_rtn;
            a.2 += (o_sq <= o_rtn + 1e-6) as usize;
            a.3 += 1;
        });
        Ok(())
    });
    ACC.with(|a| {
        let a = a.borrow();
        o_sq_total = a.0;
        o_rtn_total = a.1;
        wins = a.2;
        cases = a.3;
    });
    assert!(o_sq_total < o_rtn_total * 0.9,
            "aggregate CASE {o_sq_total:.2} vs RTN {o_rtn_total:.2}");
    assert!(wins * 10 >= cases * 8,
            "SQuant only improved {wins}/{cases} cases");
}

thread_local! {
    static ACC: std::cell::RefCell<(f64, f64, usize, usize)> =
        const { std::cell::RefCell::new((0.0, 0.0, 0, 0)) };
}

#[test]
fn scale_invariance() {
    // Scaling weights and scales by the same positive factor leaves the
    // integer grid assignment unchanged.
    forall("scale-invariance", 0x5CA1E, 60, 6, |c| {
        let (w, _) = rand_weight(c, &[9]);
        let bits = 4;
        let scales = channel_scales(&w, QuantConfig::new(bits));
        let res1 = squant(&w, &scales, SquantOpts::full(bits));
        let factor = 2.0f32;
        let w2 = w.clone().map(|v| v * factor);
        let scales2: Vec<f32> = scales.iter().map(|s| s * factor).collect();
        let res2 = squant(&w2, &scales2, SquantOpts::full(bits));
        if res1.q.data == res2.q.data {
            Ok(())
        } else {
            Err("q changed under joint rescaling".into())
        }
    });
}

#[test]
fn flips_are_plus_minus_one_from_rtn() {
    forall("flip-distance", 0xF11B, 80, 8, |c| {
        let (w, _) = rand_weight(c, &[3, 9, 25]);
        let bits = 4;
        let scales = channel_scales(&w, QuantConfig::new(bits));
        let res = squant(&w, &scales, SquantOpts::full(bits));
        let q0 = quantize_rtn(&w, &scales, bits);
        for (a, b) in res.q.data.iter().zip(&q0.data) {
            let d = (a - b).abs();
            if d != 0.0 && d != 1.0 {
                return Err(format!("flip distance {d}"));
            }
        }
        Ok(())
    });
}

#[test]
fn trace_replay_reconstructs_output() {
    forall("trace-replay", 0x7EACE, 60, 6, |c| {
        let (w, k) = rand_weight(c, &[3, 9]);
        let bits = 4;
        let scales = channel_scales(&w, QuantConfig::new(bits));
        let res = squant_traced(&w, &scales, SquantOpts::full(bits));
        let mut q = quantize_rtn(&w, &scales, bits);
        let n = w.shape[1];
        for ev in &res.trace {
            q.data[(ev.m * n + ev.n) * k + ev.i] += ev.delta;
        }
        if q.data == res.q.data {
            Ok(())
        } else {
            Err("trace replay mismatch".into())
        }
    });
}

#[test]
fn deterministic_across_runs() {
    forall("determinism", 0xD00D, 40, 8, |c| {
        let (w, _) = rand_weight(c, &[1, 9]);
        let a = squant_auto(&w, 4);
        let b = squant_auto(&w, 4);
        if a.q.data == b.q.data && a.flips_k == b.flips_k {
            Ok(())
        } else {
            Err("non-deterministic result".into())
        }
    });
}

#[test]
fn dequantized_weights_close_to_original() {
    // |w - wq| <= scale per element (relaxed constraint r_e = 1.0).
    forall("dequant-bound", 0xDE0, 60, 6, |c| {
        let (w, _) = rand_weight(c, &[9]);
        let bits = [4usize, 8][c.rng.below(2)];
        let res = squant_auto(&w, bits);
        let (m, rest) = (w.shape[0], w.numel() / w.shape[0]);
        for mi in 0..m {
            for i in 0..rest {
                let d = (w.data[mi * rest + i] - res.wq.data[mi * rest + i]).abs();
                if d > res.scales[mi] * (1.0 + 1e-4) {
                    return Err(format!("|w-wq| = {d} > s = {}", res.scales[mi]));
                }
            }
        }
        Ok(())
    });
}
