//! Model-zoo integrity — requires `make artifacts`.  Checks every trained
//! container parses, runs, and reproduces (a subsample of) its recorded
//! test accuracy through the native engine.

use squant::eval::{accuracy, tables::Env};
use squant::io::{dataset, sqnt};
use squant::nn::engine::forward;
use squant::nn::Graph;
use squant::util::pool::default_threads;

#[test]
fn all_models_load_and_forward() {
    let env = Env::load("artifacts").expect("run `make artifacts` first");
    assert!(!env.man.models.is_empty());
    for (name, entry) in &env.man.models {
        let c = sqnt::load(&entry.sqnt).unwrap();
        let graph = Graph::from_header(&c.header).unwrap();
        assert_eq!(&graph.name, name);
        assert!(!graph.quant_layers().is_empty());
        // Every referenced parameter exists with a sane shape.
        for layer in graph.quant_layers() {
            let w = &c.params[&layer.weight];
            assert_eq!(w.numel(), layer.m * layer.n * layer.k, "{name}");
        }
        let (x, _) = env.test.batch(0, 4);
        let out = forward(&graph, &c.params, &x, None, None).unwrap();
        assert_eq!(out.logits.shape, vec![4, graph.num_classes]);
        assert!(out.logits.data.iter().all(|v| v.is_finite()), "{name}");
    }
}

#[test]
fn recorded_accuracy_reproduces() {
    let env = Env::load("artifacts").expect("run `make artifacts` first");
    let mut test = dataset::load(&env.man.test_bin).unwrap();
    test.truncate(512);
    for (name, entry) in &env.man.models {
        let Some(recorded) = entry.test_acc else { continue };
        let c = sqnt::load(&entry.sqnt).unwrap();
        let graph = Graph::from_header(&c.header).unwrap();
        let acc = accuracy(&graph, &c.params, None, &test, 128,
                           default_threads())
            .unwrap();
        // 512-sample estimate vs full-set recorded value: allow 3 sigma of
        // binomial noise plus slack for engine-vs-jax numerics.
        let sigma = (recorded * (1.0 - recorded) / 512.0).sqrt();
        let tol = 3.0 * sigma + 0.03;
        assert!(
            (acc - recorded).abs() < tol,
            "{name}: recorded {recorded:.4} vs measured {acc:.4} (tol {tol:.4})"
        );
    }
}

#[test]
fn dataset_is_balanced_and_normalized() {
    let env = Env::load("artifacts").expect("run `make artifacts` first");
    let ds = dataset::load(&env.man.test_bin).unwrap();
    assert!(ds.len() >= 1000);
    let mut counts = [0usize; 10];
    for &l in &ds.labels {
        counts[l as usize] += 1;
    }
    let (mn, mx) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
    assert!(mx - mn <= 1, "class imbalance: {counts:?}");
    // Pixels roughly in [-3, 3].
    assert!(ds.images.abs_max() <= 3.5);
}
