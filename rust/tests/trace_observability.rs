//! Integration tests for the observability subsystem, end to end against
//! real router + worker-shard processes: a request trace that crosses the
//! process boundary merges into one tree under the `trace` verb; the
//! `metrics-prom` page is valid Prometheus text whose totals match the
//! JSON `stats` rollup; a request answered `busy` by a dying shard still
//! yields a complete trace carrying the failure event, and the respawned
//! shard's requests mint fresh ids with no collisions; a worker run with
//! `--log-json --trace-slow-ms 0` emits one parseable JSON document per
//! stderr line.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Read};
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

use squant::coordinator::server::Client;
use squant::serve::shard::health::HealthCfg;
use squant::serve::shard::{self, RouterCfg, RouterHandle};
use squant::serve::EngineCfg;
use squant::util::json::Json;

fn engine() -> EngineCfg {
    EngineCfg {
        workers: 2,
        queue_depth: 8,
        cache_cap: 8,
        cache_mb: 64,
        ..EngineCfg::default()
    }
}

fn spawn_with(
    shards: usize,
    engine_cfg: EngineCfg,
    health: HealthCfg,
) -> RouterHandle {
    shard::spawn_router(RouterCfg {
        shards,
        addr: "127.0.0.1:0".into(),
        exe: PathBuf::from(env!("CARGO_BIN_EXE_squant")),
        model_args: vec!["--tiny".into()],
        engine: engine_cfg,
        health,
    })
    .expect("router + shards up")
}

fn spawn(shards: usize, engine_cfg: EngineCfg) -> RouterHandle {
    spawn_with(shards, engine_cfg, Default::default())
}

fn connect(handle: &RouterHandle) -> Client {
    let mut c = Client::connect(&handle.addr.to_string()).unwrap();
    c.set_timeout(Some(Duration::from_secs(30))).unwrap();
    c
}

fn json(s: &str) -> Json {
    Json::parse(s).unwrap()
}

fn is_ok(resp: &Json) -> bool {
    matches!(resp.get("ok"), Some(Json::Bool(true)))
}

fn is_busy(resp: &Json) -> bool {
    resp.get("error")
        .and_then(|e| e.as_str().ok())
        .map(|e| e == "busy")
        .unwrap_or(false)
}

fn quantize(client: &mut Client, wbits: usize) -> Json {
    client
        .call(
            &Json::obj()
                .set("cmd", "quantize")
                .set("model", "tiny")
                .set("wbits", wbits),
        )
        .unwrap()
}

/// Every response through a tracing engine/router carries its trace id
/// as 16 lowercase hex digits.
fn trace_id(resp: &Json) -> String {
    let id = resp
        .req("trace")
        .expect("traced response")
        .as_str()
        .unwrap()
        .to_string();
    assert_eq!(id.len(), 16, "ids render as 016x hex: {id}");
    id
}

/// `{"cmd":"trace","id":...}` must return exactly one tree for the id.
fn trace_by_id(client: &mut Client, id: &str) -> Json {
    let resp = client
        .call(&Json::obj().set("cmd", "trace").set("id", id))
        .unwrap();
    assert!(is_ok(&resp), "{}", resp.dump());
    assert_eq!(resp.req("enabled").unwrap(), &Json::Bool(true));
    let traces = resp.req("traces").unwrap().as_arr().unwrap();
    assert_eq!(traces.len(), 1, "one tree per id: {}", resp.dump());
    traces[0].clone()
}

fn span_names(doc: &Json) -> Vec<String> {
    doc.req("spans")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter_map(|s| s.get("name").and_then(|n| n.as_str().ok()))
        .map(str::to_string)
        .collect()
}

fn find_span<'a>(doc: &'a Json, name: &str) -> Option<&'a Json> {
    doc.get("spans")?
        .as_arr()
        .ok()?
        .iter()
        .find(|s| s.get("name").and_then(|n| n.as_str().ok()) == Some(name))
}

/// The acceptance path: a cold `predict` through `--shards 2` quantizes
/// inline on the owning worker and batches the forward; the `trace` verb
/// answers one merged tree — router spans at the root, the worker's
/// same-id spans (admission through kernel dispatch) as its child.
#[test]
fn predict_through_two_shards_merges_into_one_trace_tree() {
    let handle = spawn(2, engine());
    let mut client = connect(&handle);

    let models = client.call(&Json::obj().set("cmd", "models")).unwrap();
    assert!(is_ok(&models), "{}", models.dump());
    let input_len = models.req("input_len").unwrap().as_usize().unwrap();

    // Cold key: the predict leads the single-flight quantize itself, so
    // its trace carries the whole pipeline, not just the batch stages.
    let req = Json::obj()
        .set("cmd", "predict")
        .set("model", "tiny")
        .set("wbits", 8usize)
        .set(
            "input",
            Json::Arr((0..input_len).map(|_| Json::from(0.0)).collect()),
        );
    let resp = client.call(&req).unwrap();
    assert!(is_ok(&resp), "{}", resp.dump());
    let id = trace_id(&resp);

    let doc = trace_by_id(&mut client, &id);
    assert_eq!(doc.req("id").unwrap().as_str().unwrap(), id);
    assert_eq!(doc.req("cmd").unwrap().as_str().unwrap(), "predict");
    assert_eq!(doc.req("status").unwrap().as_str().unwrap(), "ok");
    assert!(doc.req("total_us").unwrap().as_usize().unwrap() > 0);
    let names = span_names(&doc);
    for need in ["ingress", "route", "respond"] {
        assert!(
            names.iter().any(|n| n == need),
            "router span {need} missing: {}",
            doc.dump()
        );
    }
    let route = find_span(&doc, "route").unwrap();
    let owner =
        route.req("detail").unwrap().req("shard").unwrap().as_usize().unwrap();
    assert!(owner < 2, "{}", doc.dump());

    // Exactly one worker continued this id; its spans nest as the child.
    let kids = doc.req("children").unwrap().as_arr().unwrap();
    assert_eq!(kids.len(), 1, "{}", doc.dump());
    let kid = &kids[0];
    assert_eq!(kid.req("id").unwrap().as_str().unwrap(), id);
    assert_eq!(kid.req("shard").unwrap().as_usize().unwrap(), owner);
    assert_eq!(kid.req("status").unwrap().as_str().unwrap(), "ok");
    let wnames = span_names(kid);
    for need in [
        "ingress",
        "flight_lead",
        "disk_probe",
        "layer",
        "assemble",
        "batch_enqueue",
        "batch_wait",
        "batch_forward",
        "respond",
    ] {
        assert!(
            wnames.iter().any(|n| n == need),
            "worker span {need} missing: {}",
            kid.dump()
        );
    }
    // Per-layer compute spans carry the quantization detail, and the
    // stacked forward reports how many nodes each kernel dispatched.
    let layer = find_span(kid, "layer").unwrap().req("detail").unwrap();
    assert!(layer.req("bits").unwrap().as_usize().unwrap() >= 2);
    assert!(!layer.req("weight").unwrap().as_str().unwrap().is_empty());
    let fwd = find_span(kid, "batch_forward").unwrap().req("detail").unwrap();
    assert!(fwd.req("batch").unwrap().as_usize().unwrap() >= 1);
    let dispatched = fwd.req("int8").unwrap().as_usize().unwrap()
        + fwd.req("int4").unwrap().as_usize().unwrap()
        + fwd.req("f32").unwrap().as_usize().unwrap();
    assert!(dispatched > 0, "forward dispatched kernels: {}", kid.dump());

    handle.join();
}

/// `metrics-prom` through the router: the page parses as Prometheus text
/// exposition, its counters match the JSON `stats` rollup exactly, and
/// the per-shard kernel counters in `per_shard[]` sum to the merged ones.
#[test]
fn metrics_prom_is_valid_exposition_and_matches_stats() {
    let handle = spawn(2, engine());
    let mut client = connect(&handle);

    for wb in 2..=5usize {
        let r = quantize(&mut client, wb);
        assert!(is_ok(&r), "wbits {wb}: {}", r.dump());
    }
    let models = client.call(&Json::obj().set("cmd", "models")).unwrap();
    let input_len = models.req("input_len").unwrap().as_usize().unwrap();
    let pr = client
        .call(
            &Json::obj()
                .set("cmd", "predict")
                .set("model", "tiny")
                .set("wbits", 4usize)
                .set(
                    "input",
                    Json::Arr((0..input_len).map(|_| Json::from(0.0)).collect()),
                ),
        )
        .unwrap();
    assert!(is_ok(&pr), "{}", pr.dump());

    let prom = client.call(&Json::obj().set("cmd", "metrics-prom")).unwrap();
    assert!(is_ok(&prom), "{}", prom.dump());
    let text = prom.req("prom").unwrap().as_str().unwrap().to_string();

    // Valid exposition format: every line is a HELP/TYPE comment or a
    // `series value` sample whose value parses as a float.
    let mut series: Vec<(String, f64)> = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            assert!(
                rest.starts_with("HELP ") || rest.starts_with("TYPE "),
                "unexpected comment: {line:?}"
            );
            continue;
        }
        let (name, value) =
            line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line {line:?}"));
        assert!(!name.is_empty(), "{line:?}");
        let v: f64 = value
            .parse()
            .unwrap_or_else(|_| panic!("bad sample value in {line:?}"));
        series.push((name.to_string(), v));
    }
    assert!(text.contains("# TYPE squant_requests_total counter"), "{text}");
    assert!(text.contains("# TYPE squant_latency_seconds histogram"), "{text}");
    // The cluster page is the merged snapshot — per-shard labels only
    // appear when scraping a worker directly.
    assert!(!text.contains("shard="), "cluster page must be merged: {text}");

    let sample = |name: &str| -> usize {
        series
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("series {name} missing"))
            .1 as usize
    };
    assert_eq!(sample("squant_requests_total{cmd=\"quantize\"}"), 4);
    assert_eq!(sample("squant_requests_total{cmd=\"predict\"}"), 1);

    // The machine-readable snapshot rides along with the same counters
    // (CMDS order pins quantize at index 2).
    let by_cmd = prom.req("snapshot").unwrap().req("by_cmd").unwrap();
    assert_eq!(by_cmd.as_arr().unwrap()[2].as_usize().unwrap(), 4);

    // The JSON stats rollup agrees with the prom page, counter for
    // counter (neither fan-out verb touches these).
    let stats = client.call(&json(r#"{"cmd":"stats"}"#)).unwrap();
    assert!(is_ok(&stats), "{}", stats.dump());
    let reqs = stats.req("metrics").unwrap().req("requests").unwrap();
    assert_eq!(reqs.req("quantize").unwrap().as_usize().unwrap(), 4);
    assert_eq!(reqs.req("predict").unwrap().as_usize().unwrap(), 1);
    let kernel = stats.req("metrics").unwrap().req("kernel").unwrap();
    for k in ["int8", "int4", "f32"] {
        assert_eq!(
            sample(&format!("squant_kernel_dispatch_total{{kernel=\"{k}\"}}")),
            kernel.req(k).unwrap().as_usize().unwrap(),
            "kernel {k}: {text}"
        );
    }

    // Satellite invariant: the per-shard kernel counters in the cluster
    // doc sum to the merged totals, and the predict dispatched something.
    let per = stats
        .req("cluster")
        .unwrap()
        .req("per_shard")
        .unwrap()
        .as_arr()
        .unwrap();
    let mut sum = 0usize;
    for k in ["int8", "int4", "f32"] {
        let shards: usize = per
            .iter()
            .map(|p| p.req("kernel").unwrap().req(k).unwrap().as_usize().unwrap())
            .sum();
        assert_eq!(
            shards,
            kernel.req(k).unwrap().as_usize().unwrap(),
            "per-shard {k} rollup: {}",
            stats.dump()
        );
        sum += shards;
    }
    assert!(sum > 0, "predict dispatched kernels: {}", stats.dump());

    handle.join();
}

/// A shard dying with a request in flight answers the client `busy`, and
/// the trace of that request survives with the failure recorded: a
/// `shard_failed` event naming the shard and the suggested retry.  After
/// the respawn, new requests mint fresh trace ids — none collide with any
/// id issued before the crash.
#[cfg(unix)]
#[test]
fn shard_death_traces_busy_failure_and_respawn_mints_fresh_ids() {
    // Probing effectively off: only the data path may discover the death,
    // so the in-flight request deterministically drains as `busy` (the
    // reactor tick still drives the respawn on its own).
    let health = HealthCfg {
        period: Duration::from_secs(3600),
        timeout: Duration::from_secs(3600),
    };
    let handle = spawn_with(2, engine(), health);
    let mut client = connect(&handle);

    let mut seen: HashSet<String> = HashSet::new();
    let first = quantize(&mut client, 4);
    assert!(is_ok(&first), "{}", first.dump());
    let key_id = trace_id(&first);
    seen.insert(key_id.clone());
    for wb in [2usize, 3, 5, 6] {
        let r = quantize(&mut client, wb);
        assert!(is_ok(&r), "{}", r.dump());
        assert!(seen.insert(trace_id(&r)), "duplicate id: {}", r.dump());
    }

    // The wbits=4 key's owner is whoever answered its trace's child.
    let doc = trace_by_id(&mut client, &key_id);
    let kids = doc.req("children").unwrap().as_arr().unwrap();
    let owner = kids[0].req("shard").unwrap().as_usize().unwrap();
    let stats = client.call(&json(r#"{"cmd":"stats"}"#)).unwrap();
    let per = stats
        .req("cluster")
        .unwrap()
        .req("per_shard")
        .unwrap()
        .as_arr()
        .unwrap();
    let pid = per[owner].req("pid").unwrap().as_usize().unwrap();

    // Freeze the owner so the next request parks on it, then kill it
    // behind the router's back while the request is in flight.
    assert!(Command::new("kill")
        .args(["-STOP", &pid.to_string()])
        .status()
        .unwrap()
        .success());
    let killer = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(150));
        let _ = Command::new("kill").args(["-9", &pid.to_string()]).status();
    });
    let r = quantize(&mut client, 4);
    killer.join().unwrap();
    assert!(is_busy(&r), "drained as busy: {}", r.dump());
    assert!(
        r.req("retry_ms").unwrap().as_usize().unwrap() > 0,
        "{}",
        r.dump()
    );
    let busy_id = trace_id(&r);
    assert!(!seen.contains(&busy_id), "busy trace reused an id");

    // The failed request's trace is complete: status busy, the failure
    // event names the shard, and no worker claims the id (the owner died
    // holding its half).
    let doc = trace_by_id(&mut client, &busy_id);
    assert_eq!(doc.req("status").unwrap().as_str().unwrap(), "busy");
    let names = span_names(&doc);
    for need in ["ingress", "route", "shard_failed", "respond"] {
        assert!(
            names.iter().any(|n| n == need),
            "busy-trace span {need} missing: {}",
            doc.dump()
        );
    }
    let fail = find_span(&doc, "shard_failed").unwrap().req("detail").unwrap();
    assert_eq!(fail.req("shard").unwrap().as_usize().unwrap(), owner);
    assert!(fail.req("retry_ms").unwrap().as_usize().unwrap() > 0);
    match doc.get("children") {
        None => {}
        Some(k) => assert!(k.as_arr().unwrap().is_empty(), "{}", doc.dump()),
    }

    // Wait for the replacement, then verify the id space stays fresh.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let s = client.call(&json(r#"{"cmd":"stats"}"#)).unwrap();
        let c = s.req("cluster").unwrap();
        if c.req("alive").unwrap().as_usize().unwrap() == 2
            && c.req("respawns").unwrap().as_usize().unwrap() >= 1
        {
            break;
        }
        assert!(Instant::now() < deadline, "no respawn: {}", s.dump());
        std::thread::sleep(Duration::from_millis(50));
    }
    seen.insert(busy_id);
    for wb in [4usize, 7, 8] {
        let mut r = quantize(&mut client, wb);
        for _ in 0..20 {
            if !is_busy(&r) {
                break;
            }
            std::thread::sleep(Duration::from_millis(50));
            r = quantize(&mut client, wb);
        }
        assert!(is_ok(&r), "wbits {wb} after respawn: {}", r.dump());
        assert!(
            seen.insert(trace_id(&r)),
            "post-respawn id collided: {}",
            r.dump()
        );
    }

    handle.join();
}

/// A worker run with `--log-json --trace-slow-ms 0` slow-logs every
/// request as exactly one JSON document per stderr line, carrying the
/// same span tree the `trace` verb serves; its direct prom page labels
/// every series with the shard id.
#[test]
fn worker_emits_structured_json_slow_logs_on_stderr() {
    let mut child = Command::new(env!("CARGO_BIN_EXE_squant"))
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--shard-worker",
            "0",
            "--shards",
            "1",
            "--tiny",
            "--workers",
            "2",
            "--queue-depth",
            "8",
            "--log-json",
            "--trace-slow-ms",
            "0",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("worker process");
    let mut ready = String::new();
    BufReader::new(child.stdout.as_mut().unwrap())
        .read_line(&mut ready)
        .unwrap();
    let addr = Json::parse(ready.trim())
        .expect("ready line")
        .req("addr")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();

    let mut c = Client::connect(&addr).unwrap();
    c.set_timeout(Some(Duration::from_secs(30))).unwrap();
    let r = c
        .call(
            &Json::obj()
                .set("cmd", "quantize")
                .set("model", "tiny")
                .set("wbits", 4usize),
        )
        .unwrap();
    assert!(is_ok(&r), "{}", r.dump());
    // No router stamped an id, so the worker minted one itself.
    let id = trace_id(&r);

    // Scraped directly, the worker labels every series with its shard.
    let prom = c.call(&Json::obj().set("cmd", "metrics-prom")).unwrap();
    assert!(is_ok(&prom), "{}", prom.dump());
    let text = prom.req("prom").unwrap().as_str().unwrap();
    assert!(
        text.contains("squant_requests_total{shard=\"0\",cmd=\"quantize\"} 1"),
        "{text}"
    );

    // The shutdown reply may race the socket close; the exit is what
    // matters.
    let _ = c.call(&Json::obj().set("cmd", "shutdown"));
    let status = child.wait().unwrap();
    assert!(status.success(), "worker exit: {status:?}");

    let mut err = String::new();
    child.stderr.as_mut().unwrap().read_to_string(&mut err).unwrap();
    let mut slow = 0usize;
    for line in err.lines().filter(|l| !l.trim().is_empty()) {
        let doc = Json::parse(line)
            .unwrap_or_else(|e| panic!("stderr not JSON ({e:#}): {line:?}"));
        assert!(doc.get("event").is_some(), "{line:?}");
        assert!(doc.get("level").is_some(), "{line:?}");
        if doc.get("event").and_then(|v| v.as_str().ok()) == Some("slow_request")
        {
            slow += 1;
            if doc.req("id").unwrap().as_str().unwrap() == id {
                // The logged spans are the tree the trace verb serves.
                let spans = doc.req("spans").unwrap().as_arr().unwrap();
                assert!(
                    spans.iter().any(|s| {
                        s.get("name").and_then(|n| n.as_str().ok())
                            == Some("assemble")
                    }),
                    "{line:?}"
                );
            }
        }
    }
    assert!(slow >= 2, "every request slow-logs at threshold 0:\n{err}");
}
