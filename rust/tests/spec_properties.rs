//! Property tests for the canonical quantization spec (`quant::spec`):
//!
//!  * parse → canonical string → parse round-trips to the same spec and
//!    the same stable key hash;
//!  * the JSON form is field-order independent (same spec, same hash, no
//!    matter how the object is serialized) and round-trips via to_json;
//!  * legacy flat-field requests and `spec`-form requests for the same
//!    parameters canonicalize to the same spec (identical cache keys);
//!  * per-layer overrides naming unknown layers are rejected at the
//!    boundary.

use squant::quant::spec::{
    parse_scale, scale_label, LayerOverride, Method, QuantSpec,
};
use squant::quant::ScaleMethod;
use squant::util::json::Json;
use squant::util::prop::{forall, Case};

const ALL_METHODS: [&str; 12] = [
    "fp32",
    "rtn",
    "dfq",
    "zeroq",
    "dsg",
    "gdfq",
    "squant",
    "squant-e",
    "squant-ek",
    "squant-ec",
    "adaround",
    "dsg-adaround",
];

const PER_LAYER_METHODS: [&str; 6] =
    ["fp32", "rtn", "squant", "squant-e", "squant-ek", "squant-ec"];

const LAYER_POOL: [&str; 5] = ["w1", "wfc", "conv1", "layer2.0.conv", "fc"];

fn rand_bits(case: &mut Case) -> usize {
    2 + case.rng.below(15)
}

/// A random valid spec.  Overrides and non-max-abs scales only appear on
/// per-layer base methods (the validator's rule).
fn rand_spec(case: &mut Case) -> QuantSpec {
    let method =
        Method::parse(ALL_METHODS[case.rng.below(ALL_METHODS.len())]).unwrap();
    let abits = if case.rng.below(2) == 0 { 0 } else { rand_bits(case) };
    let mut spec = QuantSpec::uniform(method, rand_bits(case), abits);
    if method.per_layer() {
        if case.rng.below(3) == 0 {
            spec.scale = ScaleMethod::MseGrid { steps: 1 + case.rng.below(64) };
        }
        let n_overrides = case.rng.below(LAYER_POOL.len()).min(case.size);
        for _ in 0..n_overrides {
            let layer = LAYER_POOL[case.rng.below(LAYER_POOL.len())];
            let ov = match case.rng.below(3) {
                0 => LayerOverride { wbits: Some(rand_bits(case)), method: None },
                1 => LayerOverride {
                    wbits: None,
                    method: Some(
                        Method::parse(
                            PER_LAYER_METHODS
                                [case.rng.below(PER_LAYER_METHODS.len())],
                        )
                        .unwrap(),
                    ),
                },
                _ => LayerOverride {
                    wbits: Some(rand_bits(case)),
                    method: Some(
                        Method::parse(
                            PER_LAYER_METHODS
                                [case.rng.below(PER_LAYER_METHODS.len())],
                        )
                        .unwrap(),
                    ),
                },
            };
            spec = spec.with_override(layer, ov);
        }
    }
    spec.normalized()
}

#[test]
fn canonical_string_round_trips() {
    forall("spec-canonical-round-trip", 1923, 300, 5, |case| {
        let spec = rand_spec(case);
        spec.validate().map_err(|e| format!("generated spec invalid: {e}"))?;
        let canon = spec.canonical();
        let back = QuantSpec::parse(&canon)
            .map_err(|e| format!("canonical '{canon}' failed to parse: {e}"))?;
        if back != spec {
            return Err(format!("'{canon}' parsed to {back:?}, wanted {spec:?}"));
        }
        if back.key_hash() != spec.key_hash() {
            return Err(format!("hash changed across round-trip of '{canon}'"));
        }
        if back.canonical() != canon {
            return Err(format!("canonical not a fixed point: '{canon}'"));
        }
        Ok(())
    });
}

/// Reverse every object's field order (recursively) — a different but
/// equivalent JSON serialization of the same value.
fn reverse_fields(j: &Json) -> Json {
    match j {
        Json::Obj(kv) => Json::Obj(
            kv.iter()
                .rev()
                .map(|(k, v)| (k.clone(), reverse_fields(v)))
                .collect(),
        ),
        Json::Arr(items) => Json::Arr(items.iter().map(reverse_fields).collect()),
        other => other.clone(),
    }
}

#[test]
fn json_form_is_field_order_independent() {
    forall("spec-json-field-order", 0x5eed, 300, 5, |case| {
        let spec = rand_spec(case);
        let j = spec.to_json();
        let a = QuantSpec::from_json(&j)
            .map_err(|e| format!("to_json not parseable: {e}"))?;
        let b = QuantSpec::from_json(&reverse_fields(&j))
            .map_err(|e| format!("reversed JSON not parseable: {e}"))?;
        if a != spec || b != spec {
            return Err(format!("JSON round-trip drifted for {}", spec.canonical()));
        }
        if a.key_hash() != b.key_hash() {
            return Err("field order changed the key hash".to_string());
        }
        // Serialize → reparse (through the wire codec) too.
        let c = QuantSpec::from_json(&Json::parse(&j.dump()).unwrap())
            .map_err(|e| format!("dumped JSON not parseable: {e}"))?;
        if c != spec {
            return Err("dump/parse drifted".to_string());
        }
        Ok(())
    });
}

#[test]
fn legacy_flat_and_spec_requests_hash_identically() {
    forall("spec-legacy-equivalence", 7, 200, 4, |case| {
        // Uniform specs are exactly what the legacy flat form can express.
        let method =
            Method::parse(ALL_METHODS[case.rng.below(ALL_METHODS.len())]).unwrap();
        let mut spec = QuantSpec::uniform(method, rand_bits(case), {
            if case.rng.below(2) == 0 {
                0
            } else {
                rand_bits(case)
            }
        });
        if method.per_layer() && case.rng.below(3) == 0 {
            spec.scale = ScaleMethod::MseGrid { steps: 1 + case.rng.below(64) };
        }
        let flat = Json::obj()
            .set("cmd", "quantize")
            .set("model", "m")
            .set("wbits", spec.wbits)
            .set("abits", spec.abits)
            .set("method", spec.method.label())
            .set("scale", scale_label(spec.scale));
        let spec_obj = Json::obj()
            .set("cmd", "quantize")
            .set("model", "m")
            .set("spec", spec.to_json());
        let spec_str = Json::obj()
            .set("cmd", "quantize")
            .set("model", "m")
            .set("spec", spec.canonical());
        let a = QuantSpec::from_request(&flat)
            .map_err(|e| format!("flat form rejected: {e}"))?;
        let b = QuantSpec::from_request(&spec_obj)
            .map_err(|e| format!("spec object rejected: {e}"))?;
        let c = QuantSpec::from_request(&spec_str)
            .map_err(|e| format!("spec string rejected: {e}"))?;
        if a != spec || b != spec || c != spec {
            return Err(format!(
                "request forms disagree for {}: flat={}, obj={}, str={}",
                spec.canonical(),
                a.canonical(),
                b.canonical(),
                c.canonical()
            ));
        }
        if a.key_hash() != b.key_hash() || b.key_hash() != c.key_hash() {
            return Err("request forms hash differently".to_string());
        }
        Ok(())
    });
}

#[test]
fn unknown_layer_overrides_rejected() {
    forall("spec-unknown-layer", 99, 200, 4, |case| {
        let mut spec = rand_spec(case);
        if !spec.method.per_layer() {
            return Ok(()); // no overrides possible
        }
        spec = spec.with_override(
            "definitely-not-a-layer",
            LayerOverride { wbits: Some(8), method: None },
        );
        let spec = spec.normalized();
        match spec.validate_layers(LAYER_POOL.iter().copied()) {
            Err(e) if e.contains("unknown layer") => Ok(()),
            Err(e) => Err(format!("wrong error: {e}")),
            Ok(()) => Err("unknown layer accepted".to_string()),
        }
    });
}

#[test]
fn distinct_specs_hash_distinctly_in_practice() {
    // Not a cryptographic claim — just a regression guard that the spec
    // pool used across the suite doesn't collide under FNV-1a.
    use std::collections::HashMap;
    let mut seen: HashMap<u64, String> = HashMap::new();
    for w in [2usize, 3, 4, 8, 16] {
        for a in [0usize, 4, 8] {
            for m in ["squant", "squant-e", "rtn"] {
                for sc in ["max-abs", "mse-grid@32"] {
                    for ov in ["", ";wfc=w8", ";w1=fp32;wfc=w8"] {
                        let s =
                            QuantSpec::parse(&format!("w{w}a{a}:{m}:{sc}{ov}"))
                                .unwrap();
                        let canon = s.canonical();
                        if let Some(prev) =
                            seen.insert(s.key_hash(), canon.clone())
                        {
                            if prev != canon {
                                panic!("hash collision: '{prev}' vs '{canon}'");
                            }
                        }
                    }
                }
            }
        }
    }
    assert!(seen.len() > 200);
}

#[test]
fn scale_tokens_round_trip() {
    for s in ["max-abs", "mse-grid@7", "mse-grid@32"] {
        assert_eq!(scale_label(parse_scale(s).unwrap()), s);
    }
    assert_eq!(
        parse_scale("mse-grid").unwrap(),
        ScaleMethod::MseGrid { steps: 32 }
    );
    assert!(parse_scale("mse").is_err());
    assert!(parse_scale("mse-grid@x").is_err());
}
