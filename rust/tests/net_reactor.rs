//! Integration tests for the event-driven connection layer under the real
//! protocol server: protocol edge cases the reactor must preserve from the
//! thread-per-connection era (pipelining order, byte-trickled requests,
//! half-closed sockets), the new resource guarantees (no thread per
//! connection, idle reaping, `--max-conns`), and shutdown latency.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use squant::coordinator::server::{spawn, Client, ModelStore};
use squant::serve::EngineCfg;
use squant::util::json::Json;

fn tiny_store() -> Arc<ModelStore> {
    ModelStore::tiny()
}

fn cfg() -> EngineCfg {
    EngineCfg {
        workers: 2,
        queue_depth: 8,
        cache_cap: 8,
        cache_mb: 64,
        ..EngineCfg::default()
    }
}

fn read_json_line(r: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap()
}

/// N pipelined requests in one TCP segment are answered one line each, in
/// arrival order — even though the quantize in the middle completes on a
/// worker thread while the pings could answer inline.
#[test]
fn pipelined_requests_in_one_segment_answer_in_order() {
    let handle = spawn(tiny_store(), "127.0.0.1:0", cfg()).unwrap();
    let mut raw = TcpStream::connect(handle.addr).unwrap();
    raw.write_all(
        b"{\"cmd\":\"ping\"}\n\
          {\"cmd\":\"quantize\",\"model\":\"tiny\",\"wbits\":4}\n\
          {\"cmd\":\"models\"}\n\
          {\"cmd\":\"quantize\",\"model\":\"tiny\",\"wbits\":4}\n",
    )
    .unwrap();
    let mut r = BufReader::new(raw.try_clone().unwrap());
    let r1 = read_json_line(&mut r);
    assert_eq!(r1.req("pong").unwrap(), &Json::Bool(true), "{}", r1.dump());
    let r2 = read_json_line(&mut r);
    assert_eq!(r2.req("layers").unwrap().as_usize().unwrap(), 2);
    assert_eq!(r2.req("source").unwrap().as_str().unwrap(), "fresh");
    let r3 = read_json_line(&mut r);
    assert_eq!(r3.req("models").unwrap().as_arr().unwrap().len(), 1);
    let r4 = read_json_line(&mut r);
    assert_eq!(r4.req("source").unwrap().as_str().unwrap(), "mem",
               "same key pipelined again is a cache hit: {}", r4.dump());
    handle.join();
}

/// A request trickled one byte at a time frames exactly once; partial
/// lines survive across poll wakeups.  (Multi-byte UTF-8 split across
/// reads is covered at the conn/reactor unit level.)
#[test]
fn request_split_into_single_byte_writes_still_parses() {
    let handle = spawn(tiny_store(), "127.0.0.1:0", cfg()).unwrap();
    let mut raw = TcpStream::connect(handle.addr).unwrap();
    let req = "{\"cmd\":\"quantize\",\"model\":\"tiny\",\"wbits\":4}\n";
    for b in req.as_bytes() {
        raw.write_all(&[*b]).unwrap();
        raw.flush().unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    let mut r = BufReader::new(raw.try_clone().unwrap());
    let resp = read_json_line(&mut r);
    assert_eq!(resp.req("ok").unwrap(), &Json::Bool(true), "{}", resp.dump());
    assert_eq!(resp.req("layers").unwrap().as_usize().unwrap(), 2);
    handle.join();
}

/// A client that connects and never writes is reaped at the idle timeout
/// without holding resources; an active client on the same server is not.
#[test]
fn silent_connection_is_reaped_at_idle_timeout() {
    let handle = spawn(
        tiny_store(),
        "127.0.0.1:0",
        EngineCfg { idle_timeout_ms: 200, ..cfg() },
    )
    .unwrap();
    let mut silent = TcpStream::connect(handle.addr).unwrap();
    silent
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let t0 = Instant::now();
    let mut buf = [0u8; 16];
    let n = silent.read(&mut buf).unwrap();
    assert_eq!(n, 0, "server closed the silent conn");
    assert!(
        t0.elapsed() >= Duration::from_millis(150),
        "not reaped before the timeout ({:?})",
        t0.elapsed()
    );
    // A fresh active client still works and sees the reap in stats.
    let mut client = Client::connect(&handle.addr.to_string()).unwrap();
    let stats = client
        .call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap())
        .unwrap();
    let conns = stats.req("conns").unwrap();
    assert!(conns.req("idle_closed").unwrap().as_usize().unwrap() >= 1);
    let _ = client.call(&Json::parse(r#"{"cmd":"shutdown"}"#).unwrap());
    handle.join();
}

/// A client that half-closes (FIN on its write side) right after sending
/// still receives the full response before the server closes.
#[test]
fn half_closed_socket_still_receives_response() {
    let handle = spawn(tiny_store(), "127.0.0.1:0", cfg()).unwrap();
    let mut raw = TcpStream::connect(handle.addr).unwrap();
    raw.write_all(b"{\"cmd\":\"quantize\",\"model\":\"tiny\",\"wbits\":4}\n")
        .unwrap();
    raw.shutdown(std::net::Shutdown::Write).unwrap();
    let mut all = String::new();
    raw.read_to_string(&mut all).unwrap();
    let lines: Vec<&str> = all.lines().collect();
    assert_eq!(lines.len(), 1, "exactly one response: {all:?}");
    let resp = Json::parse(lines[0]).unwrap();
    assert_eq!(resp.req("ok").unwrap(), &Json::Bool(true), "{}", resp.dump());
    assert_eq!(resp.req("layers").unwrap().as_usize().unwrap(), 2);
    handle.join();
}

/// Over `--max-conns`, an accept is answered with one `overloaded` error
/// line, dropped, and counted — existing connections are unaffected.
#[test]
fn max_conns_rejections_are_counted() {
    let handle = spawn(
        tiny_store(),
        "127.0.0.1:0",
        EngineCfg { max_conns: 2, ..cfg() },
    )
    .unwrap();
    let addr = handle.addr.to_string();
    let mut c1 = Client::connect(&addr).unwrap();
    let r = c1.call(&Json::parse(r#"{"cmd":"ping"}"#).unwrap()).unwrap();
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(true));
    let _c2 = Client::connect(&addr).unwrap();
    std::thread::sleep(Duration::from_millis(50)); // let both register
    let extra = TcpStream::connect(handle.addr).unwrap();
    let mut r3 = BufReader::new(extra);
    let mut line = String::new();
    r3.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    assert_eq!(resp.req("error").unwrap().as_str().unwrap(), "overloaded");
    line.clear();
    assert_eq!(r3.read_line(&mut line).unwrap(), 0, "rejected conn closed");

    let stats = c1.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    let conns = stats.req("conns").unwrap();
    assert!(conns.req("rejected").unwrap().as_usize().unwrap() >= 1);
    assert!(conns.req("peak").unwrap().as_usize().unwrap() <= 2);
    let _ = c1.call(&Json::parse(r#"{"cmd":"shutdown"}"#).unwrap());
    handle.join();
}

/// The headline resource guarantee: opening many connections adds ZERO
/// threads — the reactor plus `--workers` serve them all.  (The old
/// server spawned one thread per connection.)
#[cfg(target_os = "linux")]
#[test]
fn thread_count_is_bounded_by_reactor_plus_workers() {
    fn thread_count() -> usize {
        std::fs::read_dir("/proc/self/task").unwrap().count()
    }
    let handle = spawn(tiny_store(), "127.0.0.1:0", cfg()).unwrap();
    let addr = handle.addr.to_string();
    let mut warm = Client::connect(&addr).unwrap();
    let r = warm.call(&Json::parse(r#"{"cmd":"ping"}"#).unwrap()).unwrap();
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(true));

    let before = thread_count();
    let mut clients: Vec<Client> = (0..64)
        .map(|_| Client::connect(&addr).unwrap())
        .collect();
    for c in clients.iter_mut() {
        let r = c.call(&Json::parse(r#"{"cmd":"ping"}"#).unwrap()).unwrap();
        assert_eq!(r.req("ok").unwrap(), &Json::Bool(true));
    }
    let after = thread_count();
    // Sibling tests in this binary run concurrently and spawn their own
    // small servers (reactor + 2 workers each), so the count can drift by
    // a few — but nowhere near the +64 a thread-per-connection server
    // would add for these clients.
    assert!(
        after < before + 32,
        "64 extra conns must not add per-conn threads: {before} -> {after}"
    );
    let _ = warm.call(&Json::parse(r#"{"cmd":"shutdown"}"#).unwrap());
    handle.join();
}

/// Shutdown wakes the poller immediately: stop + join with idle conns
/// open completes in well under 100 ms (the old accept loop slept in
/// 10 ms steps and each conn thread woke 5x/second on read timeouts).
#[test]
fn shutdown_latency_is_under_100ms() {
    let handle = spawn(tiny_store(), "127.0.0.1:0", cfg()).unwrap();
    let addr = handle.addr.to_string();
    // A few open-and-idle conns plus one that did real work.
    let _idle: Vec<Client> =
        (0..4).map(|_| Client::connect(&addr).unwrap()).collect();
    let mut client = Client::connect(&addr).unwrap();
    let r = client
        .call(&Json::parse(r#"{"cmd":"quantize","model":"tiny","wbits":4}"#).unwrap())
        .unwrap();
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(true), "{}", r.dump());

    let t0 = Instant::now();
    let r = client
        .call(&Json::parse(r#"{"cmd":"shutdown"}"#).unwrap())
        .unwrap();
    assert_eq!(r.req("bye").unwrap(), &Json::Bool(true));
    handle.join();
    assert!(
        t0.elapsed() < Duration::from_millis(100),
        "shutdown took {:?}",
        t0.elapsed()
    );
}
