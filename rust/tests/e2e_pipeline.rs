//! End-to-end pipeline tests — require `make artifacts`.  These assert the
//! *shape* of the paper's headline results on the real trained zoo:
//! 8-bit SQuant is nearly lossless, SQuant >= RTN at 4 bits, the offload
//! path agrees with the native path, and the quantized container
//! round-trips.

use squant::coordinator::{quantize_model, quantize_model_offload};
use squant::eval::{self, accuracy, tables::Env, CalibCfg, Method};
use squant::io::sqnt;
use squant::quant::ScaleMethod;
use squant::squant::SquantOpts;
use squant::util::pool::default_threads;

fn env() -> Env {
    let mut env = Env::load("artifacts").expect("run `make artifacts` first");
    env.test.truncate(512);
    env
}

#[test]
fn w8_squant_nearly_lossless() {
    let env = env();
    let (graph, params) = env.model("miniresnet18").unwrap();
    let threads = default_threads();
    let fp32 = accuracy(&graph, &params, None, &env.test, 128, threads).unwrap();
    let (qp, _) = quantize_model(&graph, &params, SquantOpts::full(8), threads);
    let q8 = accuracy(&graph, &qp, None, &env.test, 128, threads).unwrap();
    assert!(q8 >= fp32 - 0.02, "8-bit dropped too much: {fp32} -> {q8}");
}

#[test]
fn w4_squant_not_worse_than_rtn() {
    let env = env();
    for arch in ["miniresnet18", "minishufflenet"] {
        let Ok((graph, params)) = env.model(arch) else { continue };
        let threads = default_threads();
        let (sq, _) = quantize_model(&graph, &params, SquantOpts::full(4),
                                     threads);
        let rtn = squant::baselines::rtn::quantize_model(
            &graph, &params, 4, ScaleMethod::MaxAbs);
        let acc_sq = accuracy(&graph, &sq, None, &env.test, 128, threads).unwrap();
        let acc_rtn =
            accuracy(&graph, &rtn, None, &env.test, 128, threads).unwrap();
        // Binomial noise on 512 samples ~ 2.2%; require no significant loss.
        assert!(
            acc_sq >= acc_rtn - 0.03,
            "{arch}: squant {acc_sq} well below rtn {acc_rtn}"
        );
    }
}

#[test]
fn offload_path_matches_native() {
    let env = env();
    let (graph, params) = env.model("miniresnet18").unwrap();
    let rt = squant::runtime::Runtime::cpu().unwrap();
    let (native, _) = quantize_model(&graph, &params, SquantOpts::full(4), 1);
    let (offload, _, offloaded) =
        quantize_model_offload(&graph, &params, 4, &env.man, &rt).unwrap();
    assert!(offloaded > 0, "no layers offloaded — artifacts missing?");
    for layer in graph.quant_layers() {
        let a = &native[&layer.weight];
        let b = &offload[&layer.weight];
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-6, "{} differs", layer.weight);
        }
    }
}

#[test]
fn quantized_container_round_trips() {
    let env = env();
    let entry = env.man.model("miniresnet18").unwrap();
    let c = sqnt::load(&entry.sqnt).unwrap();
    let graph = squant::nn::Graph::from_header(&c.header).unwrap();
    let (qp, _) = quantize_model(&graph, &c.params, SquantOpts::full(4), 2);
    let path = std::env::temp_dir().join("squant_e2e_roundtrip.sqnt");
    sqnt::save(&path, &c.header, &qp).unwrap();
    let c2 = sqnt::load(&path).unwrap();
    for (k, v) in &qp {
        assert_eq!(&c2.params[k].data, &v.data, "{k}");
    }
}

#[test]
fn quantize_with_runs_every_method_on_real_model() {
    let mut env = env();
    env.test.truncate(128);
    let (graph, params) = env.model("minishufflenet").unwrap();
    let calib = CalibCfg { batch: 8, iters: 4, seed: 1 };
    for m in [
        Method::Dfq,
        Method::ZeroQ,
        Method::squant_full(),
    ] {
        let q = eval::quantize_with(m, &graph, &params, 6, 6, calib).unwrap();
        let acc = accuracy(&q.graph, &q.params, q.act.as_ref(), &env.test, 64,
                           default_threads())
            .unwrap();
        assert!((0.0..=1.0).contains(&acc), "{m:?}");
    }
}
