//! Integration tests for the sharded serving subsystem: a real router in
//! this process spawning real worker-shard processes from the built
//! `squant` binary.  Covers the end-to-end routing path, the cluster
//! stats rollup invariant (merged totals == per-shard sums), shared-token
//! auth through the router, the failure drain (kill a worker mid-stream:
//! the client connection never drops, the shard respawns, only its hash
//! ranges re-target), graceful stop latency, and the resource bounds
//! (one router thread in-process, exactly N worker processes).

use std::path::PathBuf;
use std::time::{Duration, Instant};

use squant::coordinator::server::Client;
use squant::serve::shard::{self, RouterCfg, RouterHandle};
use squant::serve::EngineCfg;
use squant::util::json::Json;

fn engine() -> EngineCfg {
    EngineCfg {
        workers: 2,
        queue_depth: 8,
        cache_cap: 8,
        cache_mb: 64,
        ..EngineCfg::default()
    }
}

/// Router over N tiny-store worker shards, spawned from the test binary's
/// sibling `squant` executable.
fn spawn(shards: usize, engine_cfg: EngineCfg) -> RouterHandle {
    shard::spawn_router(RouterCfg {
        shards,
        addr: "127.0.0.1:0".into(),
        exe: PathBuf::from(env!("CARGO_BIN_EXE_squant")),
        model_args: vec!["--tiny".into()],
        engine: engine_cfg,
        health: Default::default(),
    })
    .expect("router + shards up")
}

fn connect(handle: &RouterHandle) -> Client {
    let mut c = Client::connect(&handle.addr.to_string()).unwrap();
    c.set_timeout(Some(Duration::from_secs(30))).unwrap();
    c
}

fn json(s: &str) -> Json {
    Json::parse(s).unwrap()
}

fn is_ok(resp: &Json) -> bool {
    matches!(resp.get("ok"), Some(Json::Bool(true)))
}

fn is_busy(resp: &Json) -> bool {
    resp.get("error")
        .and_then(|e| e.as_str().ok())
        .map(|e| e == "busy")
        .unwrap_or(false)
}

/// Requests route through the router to real engines; identical keys land
/// on the same shard (the second identical quantize is that shard's mem
/// cache hit); the cluster rollup is self-consistent.
#[test]
fn routes_requests_and_rolls_up_consistent_cluster_stats() {
    let handle = spawn(3, engine());
    let mut client = connect(&handle);

    // Distinct (model, spec) keys spread over the ring; every one must be
    // answered by a real engine through the router.
    for wb in 2..=8usize {
        let req = Json::obj()
            .set("cmd", "quantize")
            .set("model", "tiny")
            .set("wbits", wb);
        let resp = client.call(&req).unwrap();
        assert!(is_ok(&resp), "wbits {wb}: {}", resp.dump());
        assert_eq!(resp.req("source").unwrap().as_str().unwrap(), "fresh");
    }
    // Same key again: consistent hashing sends it to the same shard, so
    // that shard's in-memory cache answers (locality survives routing).
    let again = client
        .call(&json(r#"{"cmd":"quantize","model":"tiny","wbits":4}"#))
        .unwrap();
    assert_eq!(again.req("source").unwrap().as_str().unwrap(), "mem",
               "{}", again.dump());
    // Unknown models still route deterministically and get their error
    // from a real engine (not the router).
    let bad = client
        .call(&json(r#"{"cmd":"quantize","model":"nope","wbits":4}"#))
        .unwrap();
    assert!(!is_ok(&bad), "{}", bad.dump());

    let stats = client.call(&json(r#"{"cmd":"stats"}"#)).unwrap();
    assert!(is_ok(&stats), "{}", stats.dump());
    let cluster = stats.req("cluster").unwrap();
    assert_eq!(cluster.req("shards").unwrap().as_usize().unwrap(), 3);
    assert_eq!(cluster.req("alive").unwrap().as_usize().unwrap(), 3);
    assert_eq!(cluster.req("respawns").unwrap().as_usize().unwrap(), 0);
    // The acceptance invariant: the merged counters equal the per-shard
    // sums (same docs, one fan-out — dead shards contribute zero to both).
    let per = cluster.req("per_shard").unwrap().as_arr().unwrap();
    assert_eq!(per.len(), 3);
    let sum: usize = per
        .iter()
        .map(|p| p.req("requests_total").unwrap().as_usize().unwrap())
        .sum();
    let merged = stats
        .req("metrics").unwrap()
        .req("requests_total").unwrap()
        .as_f64().unwrap() as usize;
    assert_eq!(merged, sum, "rollup mismatch: {}", stats.dump());
    assert!(sum >= 9, "all data requests counted somewhere: {}", stats.dump());

    handle.join();
}

/// `--auth-token` through the router: unauthenticated requests are
/// rejected with `error: "auth"` (and counted), authenticated ones pass
/// through to the shards — which also demand the token (the router's
/// pool connections carry it).
#[test]
fn auth_token_gates_router_requests() {
    let handle = spawn(
        2,
        EngineCfg { auth_token: Some("sesame".into()), ..engine() },
    );
    let mut client = connect(&handle);

    let denied = client
        .call(&json(r#"{"cmd":"quantize","model":"tiny","wbits":4}"#))
        .unwrap();
    assert_eq!(denied.req("error").unwrap().as_str().unwrap(), "auth");
    let wrong = client
        .call(&json(r#"{"cmd":"ping","auth":"Sesame"}"#))
        .unwrap();
    assert_eq!(wrong.req("error").unwrap().as_str().unwrap(), "auth");
    let good = client
        .call(&json(
            r#"{"cmd":"quantize","model":"tiny","wbits":4,"auth":"sesame"}"#,
        ))
        .unwrap();
    assert!(is_ok(&good), "{}", good.dump());

    let stats = client
        .call(&json(r#"{"cmd":"stats","auth":"sesame"}"#))
        .unwrap();
    assert!(is_ok(&stats), "{}", stats.dump());
    let failed = stats
        .req("conns").unwrap()
        .req("auth_failed").unwrap()
        .as_usize().unwrap();
    assert!(failed >= 2, "both bad requests counted: {}", stats.dump());

    handle.join();
}

/// Kill a worker mid-stream.  The client's connection to the router must
/// never drop: every request is answered (ok, or `busy` + `retry_ms` to
/// retry), the dead shard is respawned, and the cluster heals back to
/// all-alive.
#[test]
fn killed_shard_drains_to_busy_and_respawns() {
    let handle = spawn(3, engine());
    let mut client = connect(&handle);
    let mut chaos = connect(&handle);

    // Warm the stream, then kill shard 0 while the client keeps going.
    let r = client
        .call(&json(r#"{"cmd":"quantize","model":"tiny","wbits":4}"#))
        .unwrap();
    assert!(is_ok(&r), "{}", r.dump());
    let killed = chaos
        .call(&Json::obj().set("cmd", "shard-kill").set("shard", 0usize))
        .unwrap();
    assert!(is_ok(&killed), "{}", killed.dump());

    // Every request over the SAME client connection is answered — a busy
    // answer is a backoff hint, never a dropped connection or an error.
    let (mut answered, mut busy) = (0usize, 0usize);
    for i in 0..40usize {
        let wb = 2 + (i % 7);
        let req = Json::obj()
            .set("cmd", "quantize")
            .set("model", "tiny")
            .set("wbits", wb);
        let resp = client.call(&req).expect("connection must survive the kill");
        if is_ok(&resp) {
            answered += 1;
        } else if is_busy(&resp) {
            busy += 1;
            let ms = resp.req("retry_ms").unwrap().as_usize().unwrap();
            std::thread::sleep(Duration::from_millis(ms.min(100) as u64));
        } else {
            panic!("unexpected failure during failover: {}", resp.dump());
        }
    }
    assert_eq!(answered + busy, 40, "every request got a response");
    assert!(answered > 0, "surviving shards kept serving");

    // The router respawns the worker; the cluster heals to 3/3 alive.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stats = chaos.call(&json(r#"{"cmd":"stats"}"#)).unwrap();
        let cluster = stats.req("cluster").unwrap();
        let alive = cluster.req("alive").unwrap().as_usize().unwrap();
        let respawns = cluster.req("respawns").unwrap().as_usize().unwrap();
        if alive == 3 && respawns >= 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "cluster never healed: {}",
            stats.dump()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    // And the healed cluster serves the dead shard's old keys again.
    let r = client
        .call(&json(r#"{"cmd":"quantize","model":"tiny","wbits":4}"#))
        .unwrap();
    assert!(is_ok(&r), "{}", r.dump());

    handle.join();
}

/// Graceful stop: `shutdown` through the router drains the shards and
/// returns in well under a second (the router's stop budget bounds both
/// owed-response collection and worker reaping).
#[test]
fn graceful_stop_drains_shards_under_one_second() {
    let handle = spawn(3, engine());
    let mut client = connect(&handle);
    let r = client
        .call(&json(r#"{"cmd":"quantize","model":"tiny","wbits":4}"#))
        .unwrap();
    assert!(is_ok(&r), "{}", r.dump());

    let t0 = Instant::now();
    let bye = client.call(&json(r#"{"cmd":"shutdown"}"#)).unwrap();
    assert_eq!(bye.req("bye").unwrap(), &Json::Bool(true));
    handle.join();
    assert!(
        t0.elapsed() < Duration::from_secs(1),
        "router stop took {:?}",
        t0.elapsed()
    );
}

/// Resource bounds: the router adds ONE thread to this process (its
/// reactor multiplexes the client side and every shard pool), and runs
/// exactly N worker processes — all reaped after join.
#[cfg(target_os = "linux")]
#[test]
fn router_is_one_thread_and_n_worker_processes() {
    fn thread_count() -> usize {
        std::fs::read_dir("/proc/self/task").unwrap().count()
    }
    let before = thread_count();
    let handle = spawn(3, engine());
    let mut client = connect(&handle);
    for wb in [2usize, 4, 8] {
        let req = Json::obj()
            .set("cmd", "quantize")
            .set("model", "tiny")
            .set("wbits", wb);
        let resp = client.call(&req).unwrap();
        assert!(is_ok(&resp), "{}", resp.dump());
    }
    let after = thread_count();
    // Sibling tests in this binary run concurrently, so allow drift — but
    // nowhere near one-thread-per-shard-connection (3 shards x 3 conns).
    assert!(
        after < before + 6,
        "router must multiplex, not spawn per-shard threads: \
         {before} -> {after}"
    );

    let stats = client.call(&json(r#"{"cmd":"stats"}"#)).unwrap();
    let per = stats
        .req("cluster").unwrap()
        .req("per_shard").unwrap()
        .as_arr().unwrap();
    let pids: Vec<usize> = per
        .iter()
        .map(|p| p.req("pid").unwrap().as_usize().unwrap())
        .collect();
    assert_eq!(pids.len(), 3);
    for &pid in &pids {
        assert!(
            std::path::Path::new(&format!("/proc/{pid}")).exists(),
            "worker {pid} should be running"
        );
    }

    handle.join();
    // Every worker is shut down and reaped with the router: no process
    // leak.  (The pid dir vanishes once the child is waited on.)
    let deadline = Instant::now() + Duration::from_secs(5);
    for &pid in &pids {
        while std::path::Path::new(&format!("/proc/{pid}")).exists() {
            assert!(Instant::now() < deadline, "worker {pid} leaked");
            std::thread::sleep(Duration::from_millis(20));
        }
    }
}
