//! Integration test for the serving subsystem over real TCP: ephemeral
//! port, ping → quantize → quantize (same key) → eval → stats, asserting
//! the repeat is a cache hit and strictly faster, and that `shutdown`
//! stops the server without needing an extra nudge connection.

use std::collections::HashMap;
use std::sync::Arc;

use squant::coordinator::server::{spawn, Client, ModelStore};
use squant::io::dataset::Dataset;
use squant::nn::tiny_test_graph;
use squant::serve::EngineCfg;
use squant::tensor::Tensor;
use squant::util::json::Json;

fn tiny_store() -> Arc<ModelStore> {
    let (g, p) = tiny_test_graph(3, 4, 10);
    let mut models = HashMap::new();
    models.insert("tiny".to_string(), (g, p));
    let test = Dataset {
        images: Tensor::zeros(&[8, 3, 8, 8]),
        labels: vec![0; 8],
    };
    Arc::new(ModelStore { models, test })
}

fn cfg() -> EngineCfg {
    EngineCfg { workers: 2, queue_depth: 8, cache_cap: 8, cache_mb: 64 }
}

#[test]
fn serve_end_to_end_cache_and_stats() {
    let handle = spawn(tiny_store(), "127.0.0.1:0", cfg()).unwrap();
    let mut client = Client::connect(&handle.addr.to_string()).unwrap();

    let r = client.call(&Json::parse(r#"{"cmd":"ping"}"#).unwrap()).unwrap();
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(true));

    let quantize = Json::obj()
        .set("cmd", "quantize")
        .set("model", "tiny")
        .set("wbits", 4usize);
    let r1 = client.call(&quantize).unwrap();
    assert_eq!(r1.req("ok").unwrap(), &Json::Bool(true), "{}", r1.dump());
    assert_eq!(r1.req("cached").unwrap(), &Json::Bool(false));
    assert_eq!(r1.req("layers").unwrap().as_usize().unwrap(), 2);
    let first_ms = r1.req("served_ms").unwrap().as_f64().unwrap();

    // Same key again: must be a cache hit and strictly faster (a hit is an
    // LRU lookup; a miss runs SQuant over every layer).  Take the fastest
    // of a few hits so one unlucky scheduler preemption on a loaded CI
    // runner can't flip the comparison.
    let mut second_ms = f64::INFINITY;
    for _ in 0..5 {
        let r2 = client.call(&quantize).unwrap();
        assert_eq!(r2.req("ok").unwrap(), &Json::Bool(true), "{}", r2.dump());
        assert_eq!(r2.req("cached").unwrap(), &Json::Bool(true));
        second_ms = second_ms.min(r2.req("served_ms").unwrap().as_f64().unwrap());
    }
    assert!(
        second_ms < first_ms,
        "cache hit ({second_ms} ms) must be faster than the miss ({first_ms} ms)"
    );

    // Eval on the same key reuses the cached artifact.
    let ev = Json::obj()
        .set("cmd", "eval")
        .set("model", "tiny")
        .set("wbits", 4usize)
        .set("samples", 8usize);
    let r3 = client.call(&ev).unwrap();
    assert_eq!(r3.req("ok").unwrap(), &Json::Bool(true), "{}", r3.dump());
    assert_eq!(r3.req("cached").unwrap(), &Json::Bool(true));
    let top1 = r3.req("top1").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&top1));

    // Stats reflect the hit/miss traffic above.
    let stats = client.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    assert_eq!(stats.req("ok").unwrap(), &Json::Bool(true));
    // 5 cached quantizes + 1 cached eval on top of the single miss.
    let cache = stats.req("cache").unwrap();
    assert!(cache.req("hits").unwrap().as_usize().unwrap() >= 6, "{}", stats.dump());
    assert_eq!(cache.req("misses").unwrap().as_usize().unwrap(), 1);
    assert_eq!(cache.req("entries").unwrap().as_usize().unwrap(), 1);
    let reqs = stats.req("metrics").unwrap().req("requests").unwrap();
    assert_eq!(reqs.req("quantize").unwrap().as_usize().unwrap(), 6);
    assert_eq!(reqs.req("eval").unwrap().as_usize().unwrap(), 1);
    assert!(
        stats
            .req("metrics").unwrap()
            .req("latency").unwrap()
            .req("quantize").unwrap()
            .req("count").unwrap()
            .as_usize().unwrap()
            == 6
    );

    // Warm an artifact for a different key, then confirm it lands.
    let warm = Json::obj()
        .set("cmd", "warm")
        .set("model", "tiny")
        .set("wbits", 8usize);
    let r = client.call(&warm).unwrap();
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(true), "{}", r.dump());

    // Shutdown: the server must exit WITHOUT another connection arriving
    // (the old blocking accept loop needed a nudge); join() hangs — and the
    // test harness times out — if the fix regresses.
    let r = client.call(&Json::parse(r#"{"cmd":"shutdown"}"#).unwrap()).unwrap();
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(true));
    handle.join();
}

#[test]
fn unknown_model_and_bad_json_are_errors() {
    let handle = spawn(tiny_store(), "127.0.0.1:0", cfg()).unwrap();
    let mut client = Client::connect(&handle.addr.to_string()).unwrap();

    let r = client
        .call(&Json::obj().set("cmd", "quantize").set("model", "nope"))
        .unwrap();
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(false));

    // Malformed JSON still gets a one-line error response.
    use std::io::{BufRead, BufReader, Write};
    let mut raw = std::net::TcpStream::connect(handle.addr).unwrap();
    raw.write_all(b"{not json\n").unwrap();
    let mut line = String::new();
    BufReader::new(raw.try_clone().unwrap()).read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    assert_eq!(resp.req("ok").unwrap(), &Json::Bool(false));

    handle.join();
}
