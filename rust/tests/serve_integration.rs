//! Integration tests for the serving subsystem over real TCP: ephemeral
//! port, ping → quantize → quantize (same key) → eval → stats, asserting
//! the repeat is a cache hit and strictly faster, and that `shutdown`
//! stops the server without needing an extra nudge connection.
//!
//! The restart test exercises the disk persistence tier end-to-end with a
//! real model file: quantize, kill the server, respawn over the same
//! `--cache-dir` and require a disk hit (no SQuant recompute) — then touch
//! the model file and require the stale artifact to be invalidated.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use squant::coordinator::server::{spawn, Client, ModelStore};
use squant::io::dataset::Dataset;
use squant::io::sqnt;
use squant::nn::tiny_test_graph;
use squant::serve::EngineCfg;
use squant::tensor::Tensor;
use squant::util::json::Json;

fn test_dataset() -> Dataset {
    Dataset {
        images: Tensor::zeros(&[8, 3, 8, 8]),
        labels: vec![0; 8],
    }
}

fn tiny_store() -> Arc<ModelStore> {
    ModelStore::tiny()
}

fn cfg() -> EngineCfg {
    EngineCfg {
        workers: 2,
        queue_depth: 8,
        cache_cap: 8,
        cache_mb: 64,
        ..EngineCfg::default()
    }
}

#[test]
fn serve_end_to_end_cache_and_stats() {
    let handle = spawn(tiny_store(), "127.0.0.1:0", cfg()).unwrap();
    let mut client = Client::connect(&handle.addr.to_string()).unwrap();

    let r = client.call(&Json::parse(r#"{"cmd":"ping"}"#).unwrap()).unwrap();
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(true));

    let quantize = Json::obj()
        .set("cmd", "quantize")
        .set("model", "tiny")
        .set("wbits", 4usize);
    let r1 = client.call(&quantize).unwrap();
    assert_eq!(r1.req("ok").unwrap(), &Json::Bool(true), "{}", r1.dump());
    assert_eq!(r1.req("cached").unwrap(), &Json::Bool(false));
    assert_eq!(r1.req("source").unwrap().as_str().unwrap(), "fresh");
    assert_eq!(r1.req("layers").unwrap().as_usize().unwrap(), 2);
    let first_ms = r1.req("served_ms").unwrap().as_f64().unwrap();

    // Same key again: must be a cache hit and strictly faster (a hit is an
    // LRU lookup; a miss runs SQuant over every layer).  Take the fastest
    // of a few hits so one unlucky scheduler preemption on a loaded CI
    // runner can't flip the comparison.
    let mut second_ms = f64::INFINITY;
    for _ in 0..5 {
        let r2 = client.call(&quantize).unwrap();
        assert_eq!(r2.req("ok").unwrap(), &Json::Bool(true), "{}", r2.dump());
        assert_eq!(r2.req("cached").unwrap(), &Json::Bool(true));
        assert_eq!(r2.req("source").unwrap().as_str().unwrap(), "mem");
        second_ms = second_ms.min(r2.req("served_ms").unwrap().as_f64().unwrap());
    }
    assert!(
        second_ms < first_ms,
        "cache hit ({second_ms} ms) must be faster than the miss ({first_ms} ms)"
    );

    // Eval on the same key reuses the cached artifact.
    let ev = Json::obj()
        .set("cmd", "eval")
        .set("model", "tiny")
        .set("wbits", 4usize)
        .set("samples", 8usize);
    let r3 = client.call(&ev).unwrap();
    assert_eq!(r3.req("ok").unwrap(), &Json::Bool(true), "{}", r3.dump());
    assert_eq!(r3.req("cached").unwrap(), &Json::Bool(true));
    let top1 = r3.req("top1").unwrap().as_f64().unwrap();
    assert!((0.0..=1.0).contains(&top1));

    // Stats reflect the hit/miss traffic above.
    let stats = client.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    assert_eq!(stats.req("ok").unwrap(), &Json::Bool(true));
    // 5 cached quantizes + 1 cached eval on top of the single miss.
    let cache = stats.req("cache").unwrap();
    assert!(cache.req("hits").unwrap().as_usize().unwrap() >= 6, "{}", stats.dump());
    assert_eq!(cache.req("misses").unwrap().as_usize().unwrap(), 1);
    assert_eq!(cache.req("entries").unwrap().as_usize().unwrap(), 1);
    // No --cache-dir on this server: the disk tier reports disabled.
    let disk = cache.req("disk").unwrap();
    assert_eq!(disk.req("enabled").unwrap(), &Json::Bool(false));
    let reqs = stats.req("metrics").unwrap().req("requests").unwrap();
    assert_eq!(reqs.req("quantize").unwrap().as_usize().unwrap(), 6);
    assert_eq!(reqs.req("eval").unwrap().as_usize().unwrap(), 1);
    assert!(
        stats
            .req("metrics").unwrap()
            .req("latency").unwrap()
            .req("quantize").unwrap()
            .req("count").unwrap()
            .as_usize().unwrap()
            == 6
    );

    // Warm an artifact for a different key, then confirm it lands.
    let warm = Json::obj()
        .set("cmd", "warm")
        .set("model", "tiny")
        .set("wbits", 8usize);
    let r = client.call(&warm).unwrap();
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(true), "{}", r.dump());

    // Shutdown: the server must exit WITHOUT another connection arriving
    // (the old blocking accept loop needed a nudge); join() hangs — and the
    // test harness times out — if the fix regresses.
    let r = client.call(&Json::parse(r#"{"cmd":"shutdown"}"#).unwrap()).unwrap();
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(true));
    handle.join();
}

/// The layer-task pipeline's observability surface, end-to-end over TCP:
/// after a burst of concurrent distinct-key quantizes, `stats` exposes the
/// task gauges (`tasks {queued, running, cost_units}`), the scheduler's
/// cost capacity, and a per-flight queue/compute latency split with one
/// sample per fresh artifact.
#[test]
fn stats_expose_layer_task_pipeline() {
    let handle = spawn(tiny_store(), "127.0.0.1:0", cfg()).unwrap();
    let addr = handle.addr.to_string();
    let mut threads = Vec::new();
    for wbits in [2usize, 3, 4, 5, 6, 8] {
        let addr = addr.clone();
        threads.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            let req = Json::obj()
                .set("cmd", "quantize")
                .set("model", "tiny")
                .set("wbits", wbits);
            let r = client.call(&req).unwrap();
            assert_eq!(r.req("ok").unwrap(), &Json::Bool(true), "{}", r.dump());
            assert_eq!(r.req("source").unwrap().as_str().unwrap(), "fresh");
        }));
    }
    for t in threads {
        t.join().unwrap();
    }
    let mut client = Client::connect(&addr).unwrap();
    // All six flights answered, so the gauges drain to zero — but the
    // response fires from inside the last layer task's job, a hair before
    // the job retires its admission ticket, so poll briefly.
    let stats = {
        let mut stats = None;
        for _ in 0..100 {
            let s = client
                .call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap())
                .unwrap();
            let t = s.req("tasks").unwrap();
            let drained = t.req("queued").unwrap().as_usize().unwrap() == 0
                && t.req("running").unwrap().as_usize().unwrap() == 0
                && t.req("cost_units").unwrap().as_usize().unwrap() == 0;
            stats = Some(s);
            if drained {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        stats.unwrap()
    };
    let tasks = stats.req("tasks").unwrap();
    assert_eq!(tasks.req("queued").unwrap().as_usize().unwrap(), 0);
    assert_eq!(tasks.req("running").unwrap().as_usize().unwrap(), 0);
    assert_eq!(tasks.req("cost_units").unwrap().as_usize().unwrap(), 0);
    let sched = stats.req("sched").unwrap();
    assert_eq!(
        sched.req("cost_capacity_units").unwrap().as_usize().unwrap(),
        cfg().workers + cfg().queue_depth,
        "one cost unit per admission slot"
    );
    // Every fresh flight recorded one queue-wait and one compute sample.
    let lat = stats.req("metrics").unwrap().req("latency").unwrap();
    assert_eq!(lat.req("queue").unwrap().req("count").unwrap().as_usize().unwrap(), 6);
    assert_eq!(
        lat.req("compute").unwrap().req("count").unwrap().as_usize().unwrap(),
        6
    );
    let r = client.call(&Json::parse(r#"{"cmd":"shutdown"}"#).unwrap()).unwrap();
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(true));
    handle.join();
}

/// Predict over real TCP: responses carry each connection's own logits.
///
/// Round 1 (server A: 2 s window, max-batch 2): two connections submit
/// different inputs concurrently; the collector coalesces them into one
/// stacked forward (`batch == 2` on both responses).  Round 2 (server B:
/// zero window, so every input runs alone): the same inputs are replayed
/// sequentially and must produce byte-for-byte the same logits — proving
/// both that the batched rows were fanned back to the right connection
/// and that batching never changes an answer.  Quantization is
/// deterministic, so two servers over the same store build identical
/// artifacts.
#[test]
fn predict_batches_across_connections_and_maps_logits_back() {
    let batch_cfg = EngineCfg {
        batch_window_us: 2_000_000,
        max_batch: 2,
        ..cfg()
    };
    let handle = spawn(tiny_store(), "127.0.0.1:0", batch_cfg).unwrap();
    let addr = handle.addr.to_string();

    let input = |seed: u64| -> Vec<f64> {
        let mut rng = squant::util::rng::Rng::new(seed);
        let mut v = vec![0.0f32; 3 * 8 * 8];
        rng.fill_normal(&mut v, 1.0);
        v.into_iter().map(|x| x as f64).collect()
    };
    let predict_req = |inp: &[f64]| {
        Json::obj()
            .set("cmd", "predict")
            .set("model", "tiny")
            .set("wbits", 4usize)
            .set("input", Json::Arr(inp.iter().map(|&x| Json::Num(x)).collect()))
    };
    let logits_of = |resp: &Json| -> Vec<f64> {
        resp.req("logits")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_f64().unwrap())
            .collect()
    };

    // Warm the artifact first so both predicts enter the collector
    // together instead of racing the quantize flight.
    let mut probe = Client::connect(&addr).unwrap();
    let r = probe
        .call(&Json::obj().set("cmd", "warm").set("model", "tiny").set("wbits", 4usize))
        .unwrap();
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(true), "{}", r.dump());

    let (ia, ib) = (input(11), input(22));
    let mut threads = Vec::new();
    for inp in [ia.clone(), ib.clone()] {
        let addr = addr.clone();
        let req = predict_req(&inp);
        threads.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).unwrap();
            client.call(&req).unwrap()
        }));
    }
    let batched: Vec<Json> =
        threads.into_iter().map(|t| t.join().unwrap()).collect();
    for r in &batched {
        assert_eq!(r.req("ok").unwrap(), &Json::Bool(true), "{}", r.dump());
        assert_eq!(
            r.req("batch").unwrap().as_usize().unwrap(),
            2,
            "both inputs coalesced into one forward: {}",
            r.dump()
        );
    }
    let (la, lb) = (logits_of(&batched[0]), logits_of(&batched[1]));
    assert_eq!(la.len(), 10);
    assert_ne!(la, lb, "distinct inputs produce distinct logits");

    // `stats` exposes the predict counters and batching metrics.
    let stats = probe.call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap()).unwrap();
    let m = stats.req("metrics").unwrap();
    assert_eq!(
        m.req("requests").unwrap().req("predict").unwrap().as_usize().unwrap(),
        2
    );
    let p = m.req("predict").unwrap();
    assert_eq!(p.req("inputs").unwrap().as_usize().unwrap(), 2);
    assert_eq!(p.req("batches").unwrap().as_usize().unwrap(), 1);
    assert!((p.req("mean_batch").unwrap().as_f64().unwrap() - 2.0).abs() < 1e-9);
    assert_eq!(p.req("flush_full").unwrap().as_usize().unwrap(), 1);
    let lat = m.req("latency").unwrap();
    assert_eq!(
        lat.req("predict").unwrap().req("count").unwrap().as_usize().unwrap(),
        2
    );
    assert_eq!(
        lat.req("batch_wait").unwrap().req("count").unwrap().as_usize().unwrap(),
        2
    );
    let _ = probe.call(&Json::parse(r#"{"cmd":"shutdown"}"#).unwrap());
    handle.join();

    // Round 2: zero window — every input runs alone, and pipelined
    // requests on one connection come back in arrival order with the
    // right logits (order is the protocol's correlation).
    let single_cfg = EngineCfg { batch_window_us: 0, max_batch: 32, ..cfg() };
    let handle = spawn(tiny_store(), "127.0.0.1:0", single_cfg).unwrap();
    use std::io::{BufRead, BufReader, Write};
    let mut raw = std::net::TcpStream::connect(handle.addr).unwrap();
    let mut lines = Vec::new();
    for inp in [&ia, &ib, &ia] {
        lines.push(predict_req(inp).dump());
    }
    raw.write_all((lines.join("\n") + "\n").as_bytes()).unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());
    let mut singles = Vec::new();
    for _ in 0..3 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        singles.push(Json::parse(line.trim()).unwrap());
    }
    for r in &singles {
        assert_eq!(r.req("ok").unwrap(), &Json::Bool(true), "{}", r.dump());
        assert_eq!(r.req("batch").unwrap().as_usize().unwrap(), 1);
    }
    assert_eq!(logits_of(&singles[0]), la, "batched row == solo forward (a)");
    assert_eq!(logits_of(&singles[1]), lb, "batched row == solo forward (b)");
    assert_eq!(logits_of(&singles[2]), la, "pipelined replay keeps order");

    let mut client = Client::connect(&handle.addr.to_string()).unwrap();
    let _ = client.call(&Json::parse(r#"{"cmd":"shutdown"}"#).unwrap());
    handle.join();
}

#[test]
fn unknown_model_and_bad_json_are_errors() {
    let handle = spawn(tiny_store(), "127.0.0.1:0", cfg()).unwrap();
    let mut client = Client::connect(&handle.addr.to_string()).unwrap();

    let r = client
        .call(&Json::obj().set("cmd", "quantize").set("model", "nope"))
        .unwrap();
    assert_eq!(r.req("ok").unwrap(), &Json::Bool(false));

    // Degenerate bit-widths come back as clean JSON errors, not a panic in
    // qrange's shift (wbits 0 used to abort the worker).
    for req in [
        Json::obj().set("cmd", "quantize").set("model", "tiny").set("wbits", 0usize),
        Json::obj().set("cmd", "quantize").set("model", "tiny").set("wbits", 1usize),
        Json::obj()
            .set("cmd", "eval")
            .set("model", "tiny")
            .set("wbits", 4usize)
            .set("abits", 1usize),
    ] {
        let r = client.call(&req).unwrap();
        assert_eq!(r.req("ok").unwrap(), &Json::Bool(false), "{}", r.dump());
        assert!(r.req("error").unwrap().as_str().unwrap().contains("bits"));
    }

    // Malformed JSON still gets a one-line error response.
    use std::io::{BufRead, BufReader, Write};
    let mut raw = std::net::TcpStream::connect(handle.addr).unwrap();
    raw.write_all(b"{not json\n").unwrap();
    let mut line = String::new();
    BufReader::new(raw.try_clone().unwrap()).read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    assert_eq!(resp.req("ok").unwrap(), &Json::Bool(false));

    handle.join();
}

// ---------------------------------------------------------------------------
// disk persistence tier across server restarts
// ---------------------------------------------------------------------------

/// Write the tiny model as a real SQNT container (same IR the in-memory
/// stores use, via `nn::tiny_test_header`).  `rev` lands in the header
/// meta with a rev-dependent length, so each revision changes the file
/// size — and therefore its fingerprint — even when the filesystem mtime
/// granularity is coarse.
fn write_tiny_model(path: &Path, rev: usize) {
    let (_, params) = tiny_test_graph(3, 4, 10);
    let mut order: Vec<String> = params.keys().cloned().collect();
    order.sort();
    let header = Json::parse(&squant::nn::tiny_test_header(3, 4, 10))
        .unwrap()
        .set("tensors", sqnt::rebuild_tensor_table(&params, &order).unwrap())
        .set("meta", Json::obj().set("rev", "r".repeat(rev + 1)));
    sqnt::save(path, &header, &params).unwrap();
}

fn file_store(model_path: &PathBuf) -> Arc<ModelStore> {
    Arc::new(
        ModelStore::from_sqnt_files(
            &[("tiny".to_string(), model_path.clone())],
            test_dataset(),
        )
        .unwrap(),
    )
}

/// Acceptance: a per-layer-override request round-trips through disk spill
/// → server restart → warm hit.  The spec-form request (mixed precision:
/// classifier at 8 bits over a 4-bit base) is computed once, spilled as a
/// versioned SQNT artifact, restored by the startup scan of a brand-new
/// process, answered to `warm` straight from disk, and then served from
/// memory — no SQuant recompute anywhere after the first request.
#[test]
fn per_layer_override_round_trips_disk_restart_warm() {
    let dir = std::env::temp_dir()
        .join(format!("squant_override_warm_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let model_path = dir.join("tiny.sqnt");
    write_tiny_model(&model_path, 0);
    let cfg = EngineCfg {
        cache_dir: Some(dir.join("cache")),
        cache_disk_mb: 64,
        ..cfg()
    };
    let spec = Json::parse(
        r#"{"wbits":4,"abits":8,"method":"squant","scale":"max-abs",
            "layers":{"wfc":{"wbits":8}}}"#,
    )
    .unwrap();
    let canonical = "w4a8:squant:max-abs;wfc=w8";
    let quantize = Json::obj()
        .set("cmd", "quantize")
        .set("model", "tiny")
        .set("spec", spec.clone());
    let shutdown = Json::parse(r#"{"cmd":"shutdown"}"#).unwrap();

    // 1. Compute fresh, check the canonical spec echo, spill to disk.
    let fresh_flips;
    {
        let handle = spawn(file_store(&model_path), "127.0.0.1:0", cfg.clone())
            .unwrap();
        let mut client = Client::connect(&handle.addr.to_string()).unwrap();
        let r = client.call(&quantize).unwrap();
        assert_eq!(r.req("ok").unwrap(), &Json::Bool(true), "{}", r.dump());
        assert_eq!(r.req("source").unwrap().as_str().unwrap(), "fresh");
        assert_eq!(r.req("spec").unwrap().as_str().unwrap(), canonical);
        fresh_flips = r.req("flips").unwrap().as_usize().unwrap();
        let _ = client.call(&shutdown).unwrap();
        handle.join();
    }

    // 2. Restart: `warm` with the same spec must land from disk, and the
    //    follow-up quantize is then a memory hit with the report intact.
    {
        let handle = spawn(file_store(&model_path), "127.0.0.1:0", cfg.clone())
            .unwrap();
        let mut client = Client::connect(&handle.addr.to_string()).unwrap();
        let warm = Json::obj()
            .set("cmd", "warm")
            .set("model", "tiny")
            .set("spec", spec.clone());
        let r = client.call(&warm).unwrap();
        assert_eq!(r.req("ok").unwrap(), &Json::Bool(true), "{}", r.dump());
        assert_eq!(r.req("source").unwrap().as_str().unwrap(), "disk");

        // The spec string form resolves to the same key: memory hit.
        let r = client
            .call(
                &Json::obj()
                    .set("cmd", "quantize")
                    .set("model", "tiny")
                    .set("spec", canonical),
            )
            .unwrap();
        assert_eq!(r.req("ok").unwrap(), &Json::Bool(true), "{}", r.dump());
        assert_eq!(r.req("source").unwrap().as_str().unwrap(), "mem");
        assert_eq!(r.req("layers").unwrap().as_usize().unwrap(), 2);
        assert_eq!(r.req("flips").unwrap().as_usize().unwrap(), fresh_flips);

        // The uniform w4 key is a different artifact: nothing warm for it.
        let r = client
            .call(
                &Json::obj()
                    .set("cmd", "quantize")
                    .set("model", "tiny")
                    .set("wbits", 4usize)
                    .set("abits", 8usize),
            )
            .unwrap();
        assert_eq!(r.req("cached").unwrap(), &Json::Bool(false), "{}", r.dump());
        let _ = client.call(&shutdown).unwrap();
        handle.join();
    }
}

#[test]
fn restart_warm_start_and_fingerprint_invalidation() {
    let dir = std::env::temp_dir()
        .join(format!("squant_restart_warm_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let model_path = dir.join("tiny.sqnt");
    write_tiny_model(&model_path, 0);
    let cfg = EngineCfg {
        cache_dir: Some(dir.join("cache")),
        cache_disk_mb: 64,
        ..cfg()
    };
    let quantize = Json::obj()
        .set("cmd", "quantize")
        .set("model", "tiny")
        .set("wbits", 4usize);
    let shutdown = Json::parse(r#"{"cmd":"shutdown"}"#).unwrap();

    // 1. Cold start: the artifact is computed fresh and spilled to disk.
    let fresh_flips;
    {
        let handle = spawn(file_store(&model_path), "127.0.0.1:0", cfg.clone())
            .unwrap();
        let mut client = Client::connect(&handle.addr.to_string()).unwrap();
        let r = client.call(&quantize).unwrap();
        assert_eq!(r.req("ok").unwrap(), &Json::Bool(true), "{}", r.dump());
        assert_eq!(r.req("cached").unwrap(), &Json::Bool(false));
        assert_eq!(r.req("source").unwrap().as_str().unwrap(), "fresh");
        fresh_flips = r.req("flips").unwrap().as_usize().unwrap();
        let _ = client.call(&shutdown).unwrap();
        handle.join();
    }

    // 2. Restart over the same cache dir: the same request must be served
    //    from disk (no SQuant recompute) with the full report intact.
    {
        let handle = spawn(file_store(&model_path), "127.0.0.1:0", cfg.clone())
            .unwrap();
        let mut client = Client::connect(&handle.addr.to_string()).unwrap();
        let r = client.call(&quantize).unwrap();
        assert_eq!(r.req("ok").unwrap(), &Json::Bool(true), "{}", r.dump());
        assert_eq!(r.req("cached").unwrap(), &Json::Bool(true));
        assert_eq!(r.req("source").unwrap().as_str().unwrap(), "disk");
        assert_eq!(r.req("layers").unwrap().as_usize().unwrap(), 2);
        assert_eq!(r.req("flips").unwrap().as_usize().unwrap(), fresh_flips);

        let stats = client
            .call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap())
            .unwrap();
        let disk = stats.req("cache").unwrap().req("disk").unwrap();
        assert_eq!(disk.req("enabled").unwrap(), &Json::Bool(true));
        assert_eq!(disk.req("restored").unwrap().as_usize().unwrap(), 1);
        assert_eq!(disk.req("hits").unwrap().as_usize().unwrap(), 1);
        let _ = client.call(&shutdown).unwrap();
        handle.join();
    }

    // 3. Touch the model file: the cached artifact is now stale and must be
    //    invalidated — the request recomputes instead of serving old bits.
    write_tiny_model(&model_path, 1);
    {
        let handle = spawn(file_store(&model_path), "127.0.0.1:0", cfg).unwrap();
        let mut client = Client::connect(&handle.addr.to_string()).unwrap();
        let r = client.call(&quantize).unwrap();
        assert_eq!(r.req("ok").unwrap(), &Json::Bool(true), "{}", r.dump());
        assert_eq!(r.req("cached").unwrap(), &Json::Bool(false));
        assert_eq!(r.req("source").unwrap().as_str().unwrap(), "fresh");

        let stats = client
            .call(&Json::parse(r#"{"cmd":"stats"}"#).unwrap())
            .unwrap();
        let disk = stats.req("cache").unwrap().req("disk").unwrap();
        assert!(
            disk.req("invalidated").unwrap().as_usize().unwrap() >= 1,
            "{}",
            stats.dump()
        );
        assert_eq!(disk.req("restored").unwrap().as_usize().unwrap(), 0);
        let _ = client.call(&shutdown).unwrap();
        handle.join();
    }
}
