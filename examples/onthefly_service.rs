//! On-the-fly quantization as a service: starts the coordinator's TCP
//! server on an ephemeral port, then exercises it as a client — the
//! smartphone/IoT deployment story from the paper's introduction.
//!
//!   cargo run --release --example onthefly_service

use anyhow::Result;
use std::sync::Arc;

use squant::coordinator::server::{Client, ModelStore};
use squant::io::manifest::Manifest;
use squant::util::json::Json;

fn main() -> Result<()> {
    let man = Manifest::load("artifacts")?;
    let store = Arc::new(ModelStore::load(&man)?);
    let names: Vec<String> = store.models.keys().cloned().collect();

    // Bind on an ephemeral port, serve in the background.
    let addr = "127.0.0.1:7433";
    let store2 = Arc::clone(&store);
    let server = std::thread::spawn(move || {
        let _ = squant::coordinator::server::serve(store2, addr);
    });
    std::thread::sleep(std::time::Duration::from_millis(300));

    let mut client = Client::connect(addr)?;
    println!("connected to coordinator at {addr}");

    let resp = client.call(&Json::parse(r#"{"cmd":"models"}"#)?)?;
    println!("models: {}", resp.req("models")?.dump());

    for name in names.iter().take(2) {
        for bits in [8usize, 4] {
            let req = Json::obj()
                .set("cmd", "quantize")
                .set("model", name.as_str())
                .set("wbits", bits);
            let resp = client.call(&req)?;
            println!(
                "quantize {name} W{bits}: {} layers in {:.1} ms wall \
                 ({:.2} ms/layer, {} flips)",
                resp.req("layers")?.as_usize()?,
                resp.req("wall_ms")?.as_f64()?,
                resp.req("avg_layer_ms")?.as_f64()?,
                resp.req("flips")?.as_usize()?
            );
        }
    }

    // One full quantize+eval round trip on a subsample.
    let req = Json::obj()
        .set("cmd", "eval")
        .set("model", names[0].as_str())
        .set("wbits", 4usize)
        .set("abits", 8usize)
        .set("samples", 256usize);
    let resp = client.call(&req)?;
    println!(
        "eval {} W4A8 on {} samples: top-1 {:.2}% (quantized in {:.1} ms)",
        names[0],
        resp.req("samples")?.as_usize()?,
        resp.req("top1")?.as_f64()? * 100.0,
        resp.req("quant_ms")?.as_f64()?
    );

    let _ = client.call(&Json::parse(r#"{"cmd":"shutdown"}"#)?)?;
    // Nudge the accept loop so it notices the stop flag.
    let _ = std::net::TcpStream::connect(addr);
    let _ = server.join();
    println!("service stopped cleanly");
    Ok(())
}
