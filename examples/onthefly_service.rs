//! On-the-fly quantization as a service: starts the coordinator's TCP
//! server on an ephemeral port, then exercises it as a client — the
//! smartphone/IoT deployment story from the paper's introduction, now
//! backed by the serving subsystem (artifact cache, single-flight dedup,
//! bounded scheduler, metrics).
//!
//!   cargo run --release --example onthefly_service

use anyhow::Result;
use std::sync::Arc;

use squant::coordinator::server::{self, Client, ModelStore};
use squant::io::manifest::Manifest;
use squant::serve::EngineCfg;
use squant::util::json::Json;

fn main() -> Result<()> {
    let man = Manifest::load("artifacts")?;
    let store = Arc::new(ModelStore::load(&man)?);
    let names: Vec<String> = store.models.keys().cloned().collect();

    // Bind an ephemeral port, serve in the background.
    let handle = server::spawn(store, "127.0.0.1:0", EngineCfg::default())?;
    let addr = handle.addr.to_string();

    let mut client = Client::connect(&addr)?;
    println!("connected to coordinator at {addr}");

    let resp = client.call(&Json::parse(r#"{"cmd":"models"}"#)?)?;
    println!("models: {}", resp.req("models")?.dump());

    // Prefetch one artifact, then quantize each model twice: the repeat is
    // served from the LRU cache (cached=true, ~µs instead of ~ms).
    let warm = Json::obj()
        .set("cmd", "warm")
        .set("model", names[0].as_str())
        .set("wbits", 8usize);
    println!("warm: {}", client.call(&warm)?.dump());

    for name in names.iter().take(2) {
        for bits in [8usize, 4] {
            for round in 1..=2 {
                let req = Json::obj()
                    .set("cmd", "quantize")
                    .set("model", name.as_str())
                    .set("wbits", bits);
                let resp = client.call(&req)?;
                println!(
                    "quantize {name} W{bits} (round {round}): {} layers, \
                     served in {:.2} ms (quantize wall {:.1} ms, {} flips, \
                     cached={})",
                    resp.req("layers")?.as_usize()?,
                    resp.req("served_ms")?.as_f64()?,
                    resp.req("wall_ms")?.as_f64()?,
                    resp.req("flips")?.as_usize()?,
                    resp.req("cached")?.as_bool()?
                );
            }
        }
    }

    // Two identical quantize+eval round trips on a subsample.  Note the
    // cache key includes abits, so this W4A8 eval is a fresh artifact even
    // after the W4 (abits=0) quantizes above — but the second eval reuses
    // the first one's entry.
    let req = Json::obj()
        .set("cmd", "eval")
        .set("model", names[0].as_str())
        .set("wbits", 4usize)
        .set("abits", 8usize)
        .set("samples", 256usize);
    for round in 1..=2 {
        let resp = client.call(&req)?;
        println!(
            "eval {} W4A8 (round {round}) on {} samples: top-1 {:.2}% \
             (quantized in {:.1} ms, cached={})",
            names[0],
            resp.req("samples")?.as_usize()?,
            resp.req("top1")?.as_f64()? * 100.0,
            resp.req("quant_ms")?.as_f64()?,
            resp.req("cached")?.as_bool()?
        );
    }

    // Serving metrics: request counts, hit/miss, latency quantiles.
    let stats = client.call(&Json::parse(r#"{"cmd":"stats"}"#)?)?;
    let cache = stats.req("cache")?;
    println!(
        "stats: {} entries cached ({} hits / {} misses), p95 latency {:.2} ms",
        cache.req("entries")?.as_usize()?,
        cache.req("hits")?.as_usize()?,
        cache.req("misses")?.as_usize()?,
        stats
            .req("metrics")?
            .req("latency")?
            .req("all")?
            .req("p95_ms")?
            .as_f64()?
    );

    // Shutdown now takes effect immediately — the accept loop polls, so no
    // nudge connection is needed.
    let _ = client.call(&Json::parse(r#"{"cmd":"shutdown"}"#)?)?;
    handle.join();
    println!("service stopped cleanly");
    Ok(())
}
