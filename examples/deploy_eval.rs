//! End-to-end deployment driver (the DESIGN.md §7 validation run).
//!
//!   cargo run --release --example deploy_eval [-- --model M --wbits B --abits A]
//!
//! Full pipeline on a real trained model + real test set:
//!   1. FP32 reference accuracy (native engine);
//!   2. on-the-fly SQuant with per-layer parallelism (+ timing report);
//!   3. RTN vs SQuant accuracy with data-free activation quantization;
//!   4. the same quantized weights executed through the AOT PJRT forward
//!      graph (latency + throughput);
//!   5. quantized-container export.
//!
//! Results of this run are recorded in EXPERIMENTS.md.

use anyhow::Result;
use squant::coordinator::quantize_model;
use squant::eval::{accuracy, quantize_rtn_only, tables::Env};
use squant::io::sqnt;
use squant::nn::actrange::data_free_ranges;
use squant::runtime::Runtime;
use squant::squant::SquantOpts;
use squant::tensor::Tensor;
use squant::util::cli::Args;
use squant::util::pool::default_threads;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    let model = args.str_or("model", "miniresnet18");
    let wbits = args.usize_or("wbits", 4)?;
    let abits = args.usize_or("abits", 8)?;
    let env = Env::load(&args.str_or("artifacts", "artifacts"))?;
    let threads = default_threads();

    let entry = env.man.model(&model)?;
    let c = sqnt::load(&entry.sqnt)?;
    let graph = squant::nn::Graph::from_header(&c.header)?;
    println!("== deploy_eval: {model} W{wbits}A{abits} ({} test images) ==",
             env.test.len());

    let fp32 = accuracy(&graph, &c.params, None, &env.test, 256, threads)?;
    println!("[1] fp32 top-1 (native)       : {:.2}%", fp32 * 100.0);

    let (qparams, report) =
        quantize_model(&graph, &c.params, SquantOpts::full(wbits), threads);
    println!(
        "[2] on-the-fly quantization   : {} layers, {:.1} ms wall, {:.2} ms/layer",
        report.layers.len(), report.wall_ms, report.avg_layer_ms()
    );

    let aq = (abits > 0).then(|| data_free_ranges(&graph, &qparams, abits));
    let rtn = quantize_rtn_only(&graph, &c.params, wbits);
    let rtn_acc = accuracy(&graph, &rtn, aq.as_ref(), &env.test, 256, threads)?;
    let sq_acc =
        accuracy(&graph, &qparams, aq.as_ref(), &env.test, 256, threads)?;
    println!("[3] rtn    top-1 (native)     : {:.2}%", rtn_acc * 100.0);
    println!("    squant top-1 (native)     : {:.2}%", sq_acc * 100.0);

    if let Some(path) = entry.forward.get(&256) {
        let rt = Runtime::cpu()?;
        let exe = rt.load(path)?;
        let mut correct = 0usize;
        let mut seen = 0usize;
        let mut ms = 0.0f64;
        let mut nb = 0usize;
        let mut bi = 0;
        while bi + 256 <= env.test.len() {
            let (x, labels) = env.test.batch(bi, 256);
            let ordered: Vec<&Tensor> =
                c.order.iter().map(|n| &qparams[n]).collect();
            let mut inputs: Vec<&Tensor> = vec![&x];
            inputs.extend(ordered.iter());
            let t0 = std::time::Instant::now();
            let outs = rt.execute(&exe, &inputs)?;
            ms += t0.elapsed().as_secs_f64() * 1e3;
            nb += 1;
            for (p, l) in outs[0].argmax_rows().iter().zip(labels) {
                correct += (*p == *l as usize) as usize;
            }
            seen += labels.len();
            bi += 256;
        }
        println!(
            "[4] squant top-1 (PJRT AOT)   : {:.2}%  ({:.1} ms / 256-batch, {:.0} img/s)",
            correct as f64 / seen as f64 * 100.0,
            ms / nb as f64,
            seen as f64 / (ms / 1e3)
        );
    }

    let out = format!("artifacts/{model}_w{wbits}_deploy.sqnt");
    sqnt::save(&out, &c.header, &qparams)?;
    println!("[5] quantized container       : {out}");
    args.finish()?;
    Ok(())
}
