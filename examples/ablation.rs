//! Mini ablation playground (Table 4 interactive version): sweep the
//! SQuant stage combinations and bit widths on any zoo model and watch the
//! CASE objective track accuracy.
//!
//!   cargo run --release --example ablation [-- --model M --samples N]

use anyhow::Result;
use squant::eval::{accuracy, tables::Env};
use squant::quant::{channel_scales, perturbation, QuantConfig};
use squant::squant::{case_objective, squant, SquantOpts};
use squant::util::cli::Args;
use squant::util::pool::default_threads;

fn main() -> Result<()> {
    let mut args = Args::from_env();
    let model = args.str_or("model", "miniresnet18");
    let samples = args.usize_or("samples", 1024)?;
    let mut env = Env::load(&args.str_or("artifacts", "artifacts"))?;
    env.test.truncate(samples);
    let (graph, params) = env.model(&model)?;
    let threads = default_threads();

    println!(
        "| {:<6} | {:<14} | {:>9} | {:>12} | {:>8} |",
        "W-bit", "variant", "top-1", "CASE obj", "flips"
    );
    for bits in [3usize, 4, 6, 8] {
        for opts in [
            SquantOpts::e_only(bits),
            SquantOpts::ek(bits),
            SquantOpts::ec(bits),
            SquantOpts::full(bits),
        ] {
            let mut p = params.clone();
            let mut obj = 0.0f32;
            let mut flips = 0usize;
            for layer in graph.quant_layers() {
                let w = &params[&layer.weight];
                let scales = channel_scales(w, QuantConfig::new(bits));
                let res = squant(w, &scales, opts);
                obj += case_objective(&perturbation(w, &res.q, &scales));
                flips += res.flips_k + res.flips_c;
                p.insert(layer.weight.clone(), res.wq);
            }
            let acc = accuracy(&graph, &p, None, &env.test, 128, threads)?;
            println!(
                "| {:<6} | {:<14} | {:>8.2}% | {:>12.1} | {:>8} |",
                bits, opts.label(), acc * 100.0, obj, flips
            );
        }
    }
    args.finish()?;
    Ok(())
}
