//! Quickstart: the 60-second tour of the SQuant API.
//!
//!   cargo run --release --example quickstart
//!
//! Loads a trained model from `artifacts/`, quantizes one layer and then
//! the whole network on the fly, and shows the CASE objective + accuracy
//! effect.  Requires `make artifacts`.

use anyhow::Result;
use squant::coordinator::quantize_model;
use squant::eval::{accuracy, tables::Env};
use squant::quant::{channel_scales, perturbation, quantize_rtn, QuantConfig};
use squant::squant::{case_objective, squant, SquantOpts};
use squant::util::pool::default_threads;

fn main() -> Result<()> {
    let env = Env::load("artifacts")?;
    let (graph, params) = env.model("miniresnet18")?;
    println!("model: {} ({} quantizable layers, {} weights)",
             graph.name, graph.quant_layers().len(), graph.weight_count());

    // --- 1. Quantize a single layer ------------------------------------
    let layer = &graph.quant_layers()[1];
    let w = &params[&layer.weight];
    let bits = 4;
    let scales = channel_scales(w, QuantConfig::new(bits));
    let res = squant(w, &scales, SquantOpts::full(bits));
    let q_rtn = quantize_rtn(w, &scales, bits);
    println!(
        "\nlayer {} (M={}, N={}, K={}): {} kernel flips, {} channel flips",
        layer.weight, layer.m, layer.n, layer.k, res.flips_k, res.flips_c
    );
    println!(
        "CASE objective: rtn {:.2} -> squant {:.2}",
        case_objective(&perturbation(w, &q_rtn, &scales)),
        case_objective(&perturbation(w, &res.q, &scales))
    );

    // --- 2. Quantize the whole network on the fly ----------------------
    let threads = default_threads();
    let (qparams, report) =
        quantize_model(&graph, &params, SquantOpts::full(bits), threads);
    println!(
        "\nwhole network: {:.1} ms wall ({:.2} ms/layer avg) on {threads} threads",
        report.wall_ms, report.avg_layer_ms()
    );

    // --- 3. Accuracy before/after --------------------------------------
    let fp32 = accuracy(&graph, &params, None, &env.test, 256, threads)?;
    let q4 = accuracy(&graph, &qparams, None, &env.test, 256, threads)?;
    println!("top-1: fp32 {:.2}% -> W4 squant {:.2}%", fp32 * 100.0, q4 * 100.0);
    Ok(())
}
