"""Vectorized L2 SQuant graph (model.squant_graph, which calls the Pallas
flip kernel) vs the loop-based oracle — the parity that makes the AOT HLO
artifacts trustworthy."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model as modelmod
from compile.kernels import ref


def run_both(w, bits):
    s = ref.channel_scales_ref(w.reshape(w.shape[0], -1), bits)
    q_ref, wq_ref = ref.squant_ref(w, s, bits)
    q_jax, wq_jax = modelmod.squant_jit(jnp.asarray(w), jnp.asarray(s),
                                        bits=bits)
    return q_ref, wq_ref, np.asarray(q_jax).astype(np.int32), np.asarray(wq_jax)


@pytest.mark.parametrize("shape", [(4, 3, 9), (16, 8, 9), (8, 16, 1),
                                   (10, 10, 3), (6, 4, 25), (1, 2, 9),
                                   (3, 1, 9), (64, 8, 9)])
@pytest.mark.parametrize("bits", [3, 4, 8])
def test_parity(shape, bits):
    rng = np.random.default_rng(shape[0] * 1000 + bits)
    w = rng.normal(0, 0.1, shape).astype(np.float32)
    q_ref, wq_ref, q_jax, wq_jax = run_both(w, bits)
    np.testing.assert_array_equal(q_ref, q_jax)
    np.testing.assert_allclose(wq_ref, wq_jax, atol=1e-7)


def test_invariants_hold_on_graph_output():
    rng = np.random.default_rng(1)
    w = rng.normal(0, 0.05, (12, 6, 9)).astype(np.float32)
    s = ref.channel_scales_ref(w.reshape(12, -1), 4)
    q, _ = modelmod.squant_jit(jnp.asarray(w), jnp.asarray(s), bits=4)
    ref.check_invariants(w, np.asarray(q).astype(np.int32), s, 4)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 8), n=st.integers(1, 8),
       k=st.sampled_from([1, 3, 9]), bits=st.sampled_from([3, 4, 8]),
       seed=st.integers(0, 2 ** 16))
def test_hypothesis_parity(m, n, k, bits, seed):
    w = np.random.default_rng(seed).normal(0, 0.1, (m, n, k)).astype(np.float32)
    q_ref, _, q_jax, _ = run_both(w, bits)
    np.testing.assert_array_equal(q_ref, q_jax)
