"""Training-pipeline smoke test: a few steps on a tiny subset must reduce
loss.  Kept small so the suite stays fast; full training happens in
`make artifacts`."""

import numpy as np

import jax.numpy as jnp

from compile import datasets, ir as irmod, train as trainmod


def test_loss_decreases_on_tiny_subset():
    imgs, labels = datasets.make_split("train", 256)
    ir = irmod.ZOO["minishufflenet"]()
    params = {k: jnp.asarray(v) for k, v in irmod.init_params(
        ir, trainmod.TRAIN_SEED).items()}
    mom = {k: jnp.zeros_like(v) for k, v in params.items()}
    step, eval_logits = trainmod.make_step(ir)

    losses = []
    for it in range(12):
        i = (it * 64) % 192
        loss_params = step(params, mom,
                           jnp.asarray(imgs[i:i + 64]),
                           jnp.asarray(labels[i:i + 64]),
                           jnp.float32(0.05))
        params, mom, loss, acc = loss_params
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    # Accuracy should at least beat chance on the training batch.
    assert float(acc) > 0.1


def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.0, 1.0, 0.0]])
    labels = jnp.asarray([0, 1])
    ce = float(trainmod.cross_entropy(logits, labels))
    p0 = np.exp(2.0) / (np.exp(2.0) + 1 + np.exp(-1.0))
    p1 = np.exp(1.0) / (2 + np.exp(1.0))
    expected = -0.5 * (np.log(p0) + np.log(p1))
    assert abs(ce - expected) < 1e-5
