"""Model IR + JAX executor tests: shapes, op semantics, BN modes."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import ir as irmod, model as modelmod


def params_for(ir, seed=0):
    return {k: jnp.asarray(v) for k, v in irmod.init_params(ir, seed).items()}


@pytest.mark.parametrize("name", list(irmod.ZOO.keys()))
def test_forward_shapes(name):
    ir = irmod.ZOO[name]()
    params = params_for(ir)
    x = jnp.zeros((2, 3, 32, 32), jnp.float32)
    logits, stats = modelmod.forward_ir(ir, params, x, train=False)
    assert logits.shape == (2, 10)
    assert stats == {}


@pytest.mark.parametrize("name", list(irmod.ZOO.keys()))
def test_train_mode_updates_bn(name):
    ir = irmod.ZOO[name]()
    params = params_for(ir)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (4, 3, 32, 32))
                    .astype(np.float32))
    _, stats = modelmod.forward_ir(ir, params, x, train=True)
    n_bn = sum(1 for node in ir["nodes"] if node["op"] == "batchnorm")
    assert len(stats) == 2 * n_bn  # mean + var per BN


def test_quantizable_layers_shapes():
    ir = irmod.ZOO["miniresnet18"]()
    layers = list(irmod.quantizable_layers(ir))
    assert len(layers) == 21  # 17 convs + 3 downsample 1x1 + 1 fc
    for node, wname, (m, n, k) in layers:
        spec = next(s for s in ir["params"] if s["name"] == wname)
        if node["op"] == "conv2d":
            o, i, kh, kw = spec["shape"]
            assert (m, n, k) == (o, i, kh * kw)
        else:
            o, i = spec["shape"]
            assert (m, n, k) == (o, i, 1)


def test_depthwise_and_grouped_shapes():
    ir = irmod.ZOO["minishufflenet"]()
    convs = [n for n in ir["nodes"] if n["op"] == "conv2d"]
    groups = sorted({c["attrs"]["groups"] for c in convs})
    assert 1 in groups and 4 in groups and max(groups) > 4  # depthwise present
    # Depthwise weight has N = 1 (the degenerate SQuant-C case).
    dws = [n for n in convs if n["attrs"]["groups"] == n["attrs"]["cin"]
           and n["attrs"]["groups"] > 1]
    assert dws
    for node, wname, (m, n, k) in irmod.quantizable_layers(ir):
        if node in dws:
            assert n == 1 and k == 9


def test_channel_shuffle_semantics():
    b = irmod.Builder("t")
    nid = b.shuffle(b.input_id, 2)
    ir = b.to_ir()
    x = jnp.asarray(np.arange(8, dtype=np.float32).reshape(1, 8, 1, 1))
    out, _ = modelmod.forward_ir(ir, {}, x, train=False)
    # groups=2: [0..3 | 4..7] -> interleaved [0,4,1,5,2,6,3,7]
    np.testing.assert_array_equal(
        np.asarray(out).reshape(-1), [0, 4, 1, 5, 2, 6, 3, 7])


def test_avgpool_count_include_pad():
    b = irmod.Builder("t")
    b.avgpool(b.input_id, 3, 1, pad=1)
    ir = b.to_ir()
    x = jnp.ones((1, 1, 4, 4), jnp.float32)
    out, _ = modelmod.forward_ir(ir, {}, x, train=False)
    out = np.asarray(out)[0, 0]
    # Corner: 4 ones / 9 (count_include_pad=True convention).
    assert out[0, 0] == pytest.approx(4.0 / 9.0)
    assert out[1, 1] == pytest.approx(1.0)


def test_rect_kernel_padding_preserves_hw():
    b = irmod.Builder("t")
    c = b.conv(b.input_id, 3, 4, 1, 3)  # 1x3 kernel
    ir = b.to_ir()
    params = params_for(ir)
    x = jnp.zeros((1, 3, 8, 8), jnp.float32)
    vals = {}
    out, _ = modelmod.forward_ir(ir, params, x, train=False)
    assert out.shape == (1, 4, 8, 8)


def test_init_deterministic():
    ir = irmod.ZOO["miniresnet18"]()
    a = irmod.init_params(ir, 3)
    b = irmod.init_params(ir, 3)
    c = irmod.init_params(ir, 4)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    assert any(not np.array_equal(a[k], c[k]) for k in a)


def test_residual_add_is_identity_preserving():
    """Zero conv weights + BN(identity stats) -> residual passes through."""
    b = irmod.Builder("t")
    conv = b.conv(b.input_id, 2, 2, 3, 3)
    add = b.add(conv, b.input_id)
    ir = b.to_ir()
    params = params_for(ir)
    wname = ir["nodes"][conv]["params"]["weight"]
    params[wname] = jnp.zeros_like(params[wname])
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (1, 2, 5, 5))
                    .astype(np.float32))
    out, _ = modelmod.forward_ir(ir, params, x, train=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-6)
