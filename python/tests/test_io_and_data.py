"""SQNT container round-trip + SynthImageNet determinism tests."""

import os

import numpy as np
import pytest

from compile import datasets, ir as irmod, sqnt
from compile.common import NUM_CLASSES


class TestSqntContainer:
    def test_round_trip(self, tmp_path):
        ir = irmod.ZOO["minishufflenet"]()
        params = irmod.init_params(ir, 1)
        path = os.path.join(tmp_path, "m.sqnt")
        sqnt.write_sqnt(path, ir, params, {"test_acc": 0.5})
        header, rparams = sqnt.read_sqnt(path)
        assert header["name"] == "minishufflenet"
        assert header["meta"]["test_acc"] == 0.5
        assert len(header["nodes"]) == len(ir["nodes"])
        for k, v in params.items():
            np.testing.assert_array_equal(rparams[k], v)

    def test_offsets_contiguous(self, tmp_path):
        ir = irmod.ZOO["minishufflenet"]()
        params = irmod.init_params(ir, 1)
        path = os.path.join(tmp_path, "m.sqnt")
        sqnt.write_sqnt(path, ir, params)
        header, _ = sqnt.read_sqnt(path)
        off = 0
        for t in header["tensors"]:
            assert t["offset"] == off
            assert t["numel"] == int(np.prod(t["shape"]))
            off += t["numel"]

    def test_bad_shape_rejected(self, tmp_path):
        ir = irmod.ZOO["minishufflenet"]()
        params = irmod.init_params(ir, 1)
        name = ir["params"][0]["name"]
        params[name] = params[name][..., :1]
        with pytest.raises(AssertionError):
            sqnt.write_sqnt(os.path.join(tmp_path, "m.sqnt"), ir, params)


class TestSynthImageNet:
    def test_deterministic(self):
        a = datasets.make_image(3, "train", 17)
        b = datasets.make_image(3, "train", 17)
        np.testing.assert_array_equal(a, b)

    def test_train_test_disjoint_rng(self):
        a = datasets.make_image(3, "train", 17)
        b = datasets.make_image(3, "test", 17)
        assert not np.array_equal(a, b)

    def test_split_shapes_and_balance(self):
        imgs, labels = datasets.make_split("test", 200)
        assert imgs.shape == (200, 3, 32, 32)
        assert imgs.dtype == np.float32
        counts = np.bincount(labels, minlength=NUM_CLASSES)
        assert counts.min() == counts.max() == 20

    def test_bin_round_trip(self, tmp_path):
        imgs, labels = datasets.make_split("test", 64)
        path = os.path.join(tmp_path, "d.bin")
        datasets.write_dataset_bin(path, imgs, labels)
        with open(path, "rb") as f:
            assert f.read(4) == b"SDSB"
            ver, n, c, h, w = np.frombuffer(f.read(20), "<u4")
        assert (ver, n, c, h, w) == (1, 64, 3, 32, 32)
        sz = os.path.getsize(path)
        assert sz == 24 + 64 * 3 * 32 * 32 * 4 + 64 * 4

    def test_classes_separable_by_simple_stat(self):
        """Sanity: different classes differ in mean image more than noise."""
        means = []
        for cls in range(3):
            imgs = np.stack([datasets.make_image(cls, "train", i)
                             for i in range(20)])
            means.append(imgs.mean(axis=0))
        d01 = np.abs(means[0] - means[1]).mean()
        within = np.abs(
            np.stack([datasets.make_image(0, "train", i) for i in range(20)])
            - means[0]).mean()
        assert d01 > 0.01  # classes are distinguishable in expectation
        assert within > d01 * 0.2  # but with real intra-class variation
