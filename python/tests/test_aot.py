"""AOT lowering tests: HLO text generation for squant + forward graphs.

These keep the build-path honest without requiring the full (slow) artifact
build: tiny shapes only.
"""

import numpy as np

import jax
import jax.numpy as jnp

from compile import aot, ir as irmod, model as modelmod
from compile.kernels import ref


def test_lower_squant_hlo_text():
    text = aot.lower_squant(4, 3, 9, 4)
    assert "HloModule" in text
    assert "ROOT" in text
    # Tuple of two f32[4,3,9] results (q and wq).
    assert "f32[4,3,9]" in text


def test_lower_squant_executes_same_as_jit():
    """The lowered HLO must compute the same function as squant_jit —
    executed via jax from the same lowering path."""
    w = np.random.default_rng(0).normal(0, 0.1, (4, 3, 9)).astype(np.float32)
    s = ref.channel_scales_ref(w.reshape(4, -1), 4)
    compiled = jax.jit(
        lambda w_, s_: modelmod.squant_graph(w_, s_, bits=4)
    ).lower(jnp.asarray(w), jnp.asarray(s)).compile()
    q1, _ = compiled(jnp.asarray(w), jnp.asarray(s))
    q2, _ = ref.squant_ref(w, s, 4)
    np.testing.assert_array_equal(np.asarray(q1).astype(np.int32), q2)


def test_lower_forward_tiny_ir():
    b = irmod.Builder("tiny")
    x = b.conv_bn_relu(b.input_id, 3, 4, 3, 3)
    x = b.gap(x)
    b.linear(x, 4, 10)
    ir = b.to_ir()
    text = aot.lower_forward(ir, batch=2)
    assert "HloModule" in text
    assert "f32[2,10]" in text  # logits shape present


def test_forward_flat_matches_dict_forward():
    ir = irmod.ZOO["minishufflenet"]()
    params = {k: jnp.asarray(v) for k, v in irmod.init_params(ir, 2).items()}
    flat = [params[s["name"]] for s in ir["params"]]
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (2, 3, 32, 32))
                    .astype(np.float32))
    (logits_flat,) = modelmod.forward_flat(ir, x, flat)
    logits_dict, _ = modelmod.forward_ir(ir, params, x, train=False)
    np.testing.assert_allclose(np.asarray(logits_flat),
                               np.asarray(logits_dict), atol=1e-5)
