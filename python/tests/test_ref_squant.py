"""Semantic tests for the reference SQuant oracle (kernels/ref.py).

The oracle defines the behaviour every other implementation (Pallas L1,
vectorized JAX L2, native Rust L3) is held to, so these tests pin down the
paper's claimed post-conditions (Eq. 9-12) and all edge cases.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def rand_w(m, n, k, seed=0, scale=0.1):
    return np.random.default_rng(seed).normal(0, scale, (m, n, k)).astype(
        np.float32)


def scales_for(w, bits):
    return ref.channel_scales_ref(w.reshape(w.shape[0], -1), bits)


class TestRounding:
    def test_rn_half_up(self):
        assert ref.rn(0.5) == 1.0
        assert ref.rn(-0.5) == 0.0  # floor(-0.5 + 0.5) = 0
        assert ref.rn(1.5) == 2.0
        assert ref.rn(2.4) == 2.0
        assert ref.rn(-1.6) == -2.0

    def test_qrange_symmetric(self):
        assert ref.qrange(4) == (-7, 7)
        assert ref.qrange(8) == (-127, 127)
        assert ref.qrange(3) == (-3, 3)

    def test_sign_zero(self):
        assert ref.sign(0.0) == 0.0
        assert ref.sign(1e-30) == 1.0
        assert ref.sign(-1e-30) == -1.0


class TestFlipRow:
    def test_no_flip_when_small(self):
        q = np.array([1.0, -2.0, 3.0], np.float32)
        p = np.array([0.1, -0.2, 0.3], np.float32)
        e = float(p.sum())  # 0.2 -> k = 0
        idx, val = ref.flip_row(q, p, e, -7, 7)
        assert np.array_equal(q, [1.0, -2.0, 3.0])
        # Under-SQuant candidate: largest same-sign |p| = index 2.
        assert idx == 2 and val == pytest.approx(0.3)

    def test_flip_reduces_ase(self):
        rng = np.random.default_rng(3)
        for _ in range(200):
            k = int(rng.integers(2, 16))
            p = rng.uniform(-0.5, 0.5, k).astype(np.float32)
            q = ref.rn(rng.normal(0, 2, k)).astype(np.float32)
            e = float(p.sum())
            q0, p0 = q.copy(), p.copy()
            ref.flip_row(q, p, e, -100, 100)
            assert abs(p.sum()) <= 0.5 + 1e-5
            # Flips are +-1 integer mutations of the same sign as e.
            d = q - q0
            assert np.all(np.isin(d, [-1.0, 0.0, 1.0]))
            if e != 0:
                assert np.all(d * np.sign(e) <= 0)
            # Perturbation updated consistently.
            np.testing.assert_allclose(p - p0, d, atol=1e-6)

    def test_zero_e_no_candidate(self):
        q = np.zeros(4, np.float32)
        p = np.array([0.2, -0.2, 0.1, -0.1], np.float32)
        idx, val = ref.flip_row(q, p, 0.0, -7, 7)
        assert idx == -1 and val == 0.0
        assert np.array_equal(q, np.zeros(4))

    def test_grid_saturation_masks_elements(self):
        # All eligible elements sit at qmax: flipping down is q-1, fine; but
        # flipping *up* past qmax must be blocked.
        q = np.array([-7.0, -7.0, -7.0], np.float32)
        p = np.array([-0.4, -0.4, -0.4], np.float32)
        e = float(p.sum())  # -1.2 -> k=1, sgn=-1, flip means q+1? no: q-(-1)=q+1
        # q - sgn = q + 1 = -6 in grid: eligible.
        ref.flip_row(q, p, e, -7, 7)
        assert q.max() == -6.0  # exactly one flipped up
        # Now saturate the other direction: flipping would need q = -8.
        q2 = np.array([7.0, 7.0, 7.0], np.float32)
        p2 = np.array([0.4, 0.4, 0.4], np.float32)
        before = q2.copy()
        ref.flip_row(q2, p2, float(p2.sum()), 7, 7)  # degenerate grid [7,7]
        assert np.array_equal(q2, before)  # nothing eligible -> no flips

    def test_over_squant_candidate_value(self):
        # e = 1.6 -> k = 2 > |e|? no: 2 > 1.6 -> over. Candidate = 2nd flipped,
        # value = original - 1 in [-1, -0.5).
        q = np.array([1.0, 1.0, 0.0, 0.0], np.float32)
        p = np.array([0.45, 0.40, 0.40, 0.35], np.float32)
        e = float(p.sum())  # 1.6
        idx, val = ref.flip_row(q, p, e, -7, 7)
        assert idx == 1 and val == pytest.approx(0.40 - 1.0)
        assert abs(p.sum()) <= 0.5 + 1e-6

    def test_under_squant_candidate_value(self):
        # e = 1.4 -> k = 1 < |e| -> under. Candidate = 2nd largest eligible,
        # unflipped, value in (0, 0.5].
        q = np.array([1.0, 1.0, 0.0, 0.0], np.float32)
        p = np.array([0.45, 0.40, 0.30, 0.25], np.float32)
        e = float(p.sum())  # 1.4
        idx, val = ref.flip_row(q, p, e, -7, 7)
        assert idx == 1 and val == pytest.approx(0.40)

    def test_tie_breaks_to_lower_index(self):
        q = np.array([0.0, 0.0, 0.0], np.float32)
        p = np.array([0.4, 0.4, 0.4], np.float32)
        ref.flip_row(q, p, float(p.sum()), -7, 7)  # e=1.2, k=1
        assert q[0] == -1.0 and q[1] == 0.0 and q[2] == 0.0


class TestProgressive:
    @pytest.mark.parametrize("bits", [3, 4, 6, 8])
    @pytest.mark.parametrize("shape", [(4, 3, 9), (8, 8, 1), (2, 16, 3),
                                       (16, 4, 25), (1, 1, 9)])
    def test_invariants(self, bits, shape):
        w = rand_w(*shape, seed=bits * 100 + shape[0])
        s = scales_for(w, bits)
        q, wq = ref.squant_ref(w, s, bits)
        ref.check_invariants(w, q, s, bits)
        np.testing.assert_allclose(wq, q * s[:, None, None], rtol=1e-6)

    @pytest.mark.parametrize("ek,ec", [(True, False), (False, True)])
    def test_ablation_invariants(self, ek, ec):
        w = rand_w(6, 5, 9, seed=11)
        s = scales_for(w, 4)
        q, _ = ref.squant_ref(w, s, 4, enable_k=ek, enable_c=ec)
        ref.check_invariants(w, q, s, 4, enable_k=ek, enable_c=ec)

    def test_e_only_is_rtn(self):
        w = rand_w(4, 4, 9, seed=5)
        s = scales_for(w, 4)
        q, _ = ref.squant_ref(w, s, 4, enable_k=False, enable_c=False)
        q_rtn, _ = ref.rtn_ref(w, s, 4)
        assert np.array_equal(q, q_rtn)

    def test_zero_weights_untouched(self):
        w = np.zeros((3, 4, 9), np.float32)
        s = np.ones((3,), np.float32)
        q, wq = ref.squant_ref(w, s, 4)
        assert np.all(q == 0) and np.all(wq == 0)

    def test_case_objective_improves_in_aggregate(self):
        """SQuant reduces the Eq. (8) objective vs rounding in aggregate.

        (Strict per-instance descent is not guaranteed: a flip may trade a
        +0.1 element-term increase for a -0.02 kernel-term decrease when a
        kernel's ASE sits just above 0.5 — the algorithm enforces the
        *constraints*, which the invariant tests cover.)"""
        o_sq, o_rtn = 0.0, 0.0
        for seed in range(20):
            w = rand_w(8, 6, 9, seed=seed)
            s = scales_for(w, 4)
            q_sq, _ = ref.squant_ref(w, s, 4)
            q_rtn, _ = ref.rtn_ref(w, s, 4)
            def objective(q):
                p = ref.perturbation(w, q.astype(np.float32), s)
                return (np.sum(p ** 2)
                        + np.sum(p.sum(-1) ** 2)
                        + np.sum(p.sum((1, 2)) ** 2))
            o_sq += objective(q_sq)
            o_rtn += objective(q_rtn)
        assert o_sq < o_rtn

    def test_flip_count_matches_case(self):
        """#flips per kernel equals rn(|kernel ASE|) (paper Eq. 10 / B.1)."""
        w = rand_w(6, 4, 9, seed=9)
        s = scales_for(w, 4)
        qmin, qmax = ref.qrange(4)
        t = w / s[:, None, None]
        q0 = np.clip(ref.rn(t), qmin, qmax)
        p0 = q0 - t
        q, _ = ref.squant_ref(w, s, 4, enable_k=True, enable_c=False)
        flips = np.abs(q - q0).sum(axis=-1)
        expected = ref.rn(np.abs(p0.sum(-1)))
        np.testing.assert_array_equal(flips, expected)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 6), n=st.integers(1, 8),
    k=st.sampled_from([1, 3, 9, 25]),
    bits=st.sampled_from([3, 4, 8]),
    seed=st.integers(0, 2 ** 16),
    wscale=st.sampled_from([0.01, 0.1, 1.0]),
)
def test_hypothesis_invariants(m, n, k, bits, seed, wscale):
    w = rand_w(m, n, k, seed=seed, scale=wscale)
    s = scales_for(w, bits)
    q, _ = ref.squant_ref(w, s, bits)
    ref.check_invariants(w, q, s, bits)
