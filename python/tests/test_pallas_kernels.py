"""L1 Pallas kernels vs the pure-numpy oracles (hypothesis-swept).

Pallas runs under interpret=True (CPU PJRT cannot execute Mosaic) — these
tests pin the *semantics*; the Rust integration suite then checks the same
numbers come out of the AOT HLO artifacts.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import fake_quant, qmatmul, ref, squant_flip


def flip_rows_oracle(q, p, e, qmin, qmax):
    q, p = q.copy(), p.copy()
    idxs = np.full((q.shape[0],), -1, np.int32)
    vals = np.zeros((q.shape[0],), np.float32)
    for r in range(q.shape[0]):
        idxs[r], vals[r] = ref.flip_row(q[r], p[r], float(e[r]), qmin, qmax)
    return q, p, idxs, vals


def make_rows(rows, width, seed, pscale=0.5):
    rng = np.random.default_rng(seed)
    t = rng.normal(0, 2.0, (rows, width)).astype(np.float32)
    q = ref.rn(t).astype(np.float32)
    q = np.clip(q, -7, 7)
    p = (q - t).astype(np.float32)
    e = p.sum(axis=1).astype(np.float32)
    return q, p, e


class TestFlipRows:
    @pytest.mark.parametrize("rows,width", [(1, 3), (5, 9), (64, 9), (70, 25),
                                            (128, 4), (3, 1)])
    def test_matches_oracle(self, rows, width):
        q, p, e = make_rows(rows, width, seed=rows * 31 + width)
        qo, po, io_, vo = flip_rows_oracle(q, p, e, -7, 7)
        qj, pj, ij, vj = squant_flip.flip_rows(
            jnp.asarray(q), jnp.asarray(p), jnp.asarray(e), qmin=-7, qmax=7)
        np.testing.assert_array_equal(np.asarray(qj), qo)
        np.testing.assert_allclose(np.asarray(pj), po, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(ij), io_)
        np.testing.assert_allclose(np.asarray(vj), vo, atol=1e-6)

    def test_row_block_sizes_equivalent(self):
        q, p, e = make_rows(100, 9, seed=77)
        outs = []
        for rb in (1, 16, 64, 256):
            qj, pj, ij, vj = squant_flip.flip_rows(
                jnp.asarray(q), jnp.asarray(p), jnp.asarray(e),
                qmin=-7, qmax=7, row_block=rb)
            outs.append((np.asarray(qj), np.asarray(ij)))
        for a, b in zip(outs, outs[1:]):
            np.testing.assert_array_equal(a[0], b[0])
            np.testing.assert_array_equal(a[1], b[1])

    def test_zero_rows_noop(self):
        q = np.zeros((4, 9), np.float32)
        p = np.zeros((4, 9), np.float32)
        e = np.zeros((4,), np.float32)
        qj, pj, ij, vj = squant_flip.flip_rows(
            jnp.asarray(q), jnp.asarray(p), jnp.asarray(e), qmin=-7, qmax=7)
        assert np.all(np.asarray(qj) == 0)
        assert np.all(np.asarray(ij) == -1)

    @settings(max_examples=25, deadline=None)
    @given(rows=st.integers(1, 40), width=st.sampled_from([1, 3, 5, 9, 25]),
           seed=st.integers(0, 2 ** 16),
           bits=st.sampled_from([3, 4, 8]))
    def test_hypothesis_parity(self, rows, width, seed, bits):
        qmin, qmax = ref.qrange(bits)
        rng = np.random.default_rng(seed)
        t = rng.normal(0, qmax / 2, (rows, width)).astype(np.float32)
        q = np.clip(ref.rn(t), qmin, qmax).astype(np.float32)
        p = (q - t).astype(np.float32)
        e = p.sum(axis=1).astype(np.float32)
        qo, po, io_, vo = flip_rows_oracle(q, p, e, qmin, qmax)
        qj, pj, ij, vj = squant_flip.flip_rows(
            jnp.asarray(q), jnp.asarray(p), jnp.asarray(e),
            qmin=qmin, qmax=qmax)
        np.testing.assert_array_equal(np.asarray(qj), qo)
        np.testing.assert_array_equal(np.asarray(ij), io_)


class TestFakeQuant:
    @pytest.mark.parametrize("rows,cols,bits", [(8, 27, 4), (64, 9, 8),
                                                (1, 1, 3), (100, 64, 4)])
    def test_matches_oracle(self, rows, cols, bits):
        rng = np.random.default_rng(rows + cols)
        w = rng.normal(0, 0.2, (rows, cols)).astype(np.float32)
        s = ref.channel_scales_ref(w, bits)
        qmin, qmax = ref.qrange(bits)
        out = fake_quant.fake_quant(jnp.asarray(w), jnp.asarray(s),
                                    qmin=qmin, qmax=qmax)
        np.testing.assert_allclose(
            np.asarray(out), ref.fake_quant_ref(w, s, bits), atol=1e-6)

    def test_idempotent(self):
        rng = np.random.default_rng(5)
        w = rng.normal(0, 0.2, (16, 32)).astype(np.float32)
        s = ref.channel_scales_ref(w, 4)
        once = np.asarray(fake_quant.fake_quant(
            jnp.asarray(w), jnp.asarray(s), qmin=-7, qmax=7))
        twice = np.asarray(fake_quant.fake_quant(
            jnp.asarray(once), jnp.asarray(s), qmin=-7, qmax=7))
        np.testing.assert_allclose(once, twice, atol=1e-6)


class TestQMatmul:
    @pytest.mark.parametrize("b,o,cin", [(4, 10, 64), (32, 32, 128),
                                         (1, 7, 9), (33, 17, 50)])
    def test_matches_oracle(self, b, o, cin):
        rng = np.random.default_rng(b * o)
        x = rng.normal(0, 1, (b, cin)).astype(np.float32)
        q = ref.rn(rng.normal(0, 3, (o, cin))).astype(np.float32)
        s = rng.uniform(0.01, 0.1, o).astype(np.float32)
        y = qmatmul.qmatmul(jnp.asarray(x), jnp.asarray(q), jnp.asarray(s))
        np.testing.assert_allclose(
            np.asarray(y), ref.qmatmul_ref(x, q, s), rtol=2e-4, atol=2e-4)

    def test_block_sizes_equivalent(self):
        rng = np.random.default_rng(9)
        x = rng.normal(0, 1, (48, 40)).astype(np.float32)
        q = ref.rn(rng.normal(0, 3, (24, 40))).astype(np.float32)
        s = rng.uniform(0.01, 0.1, 24).astype(np.float32)
        y1 = np.asarray(qmatmul.qmatmul(
            jnp.asarray(x), jnp.asarray(q), jnp.asarray(s), b_block=8, o_block=8))
        y2 = np.asarray(qmatmul.qmatmul(
            jnp.asarray(x), jnp.asarray(q), jnp.asarray(s), b_block=64, o_block=64))
        np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)
