"""Shared constants and numeric helpers for the SQuant compile pipeline.

Everything here must stay bit-compatible with the Rust implementation in
``rust/src`` — in particular the rounding convention.  Both layers use
*round-half-up* implemented as ``floor(x + 0.5)`` (NOT banker's rounding,
which is what ``jnp.round`` / ``f32::round_ties_even`` would give), so that
the native Rust SQuant and the AOT JAX/Pallas SQuant agree bit-exactly.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Global seeds / dataset geometry (mirrored by rust/src/io/dataset.rs).
# ---------------------------------------------------------------------------
DATASET_SEED = 20220131  # ICLR 2022 :-)
NUM_CLASSES = 10
IMG_C, IMG_H, IMG_W = 3, 32, 32
TRAIN_N = 8192
TEST_N = 2048

# Container magics (mirrored by rust/src/io/*.rs).
SQNT_MAGIC = b"SQNT"
SQNT_VERSION = 1
DSET_MAGIC = b"SDSB"
DSET_VERSION = 1


def rn(x):
    """Round-half-up for jnp arrays: floor(x + 0.5).

    Matches ``squant::quant::rn`` on the Rust side.  We deliberately avoid
    ``jnp.round`` (ties-to-even) so the two SQuant implementations are
    bit-identical on .5 grid points.
    """
    return jnp.floor(x + 0.5)


def rn_np(x):
    """Numpy twin of :func:`rn`."""
    return np.floor(x + 0.5)


def qrange(bits: int):
    """Symmetric signed integer grid for ``bits``-bit quantization.

    Returns (qmin, qmax) = (-(2^{b-1} - 1), 2^{b-1} - 1).  The grid is
    symmetric (no -2^{b-1}) which keeps per-channel symmetric quantization
    sign-balanced — the convention SQuant and all our baselines use.
    """
    qmax = (1 << (bits - 1)) - 1
    return -qmax, qmax


def channel_scales(w2d, bits: int):
    """Per-output-channel max-abs scales for a (M, N*K) weight matrix."""
    _, qmax = qrange(bits)
    absmax = jnp.max(jnp.abs(w2d), axis=1)
    # Guard all-zero channels.
    absmax = jnp.where(absmax <= 0.0, 1.0, absmax)
    return absmax / qmax
