"""Pure-numpy reference oracles for every kernel in the stack.

These are the *semantic ground truth*: slow, loop-based, written to follow
the paper's Algorithms 1/2/4 line by line.  Both the Pallas kernels (L1) and
the Rust native implementation (L3, ``rust/src/squant``) are tested against
the behaviour defined here; the Rust integration suite additionally checks
bit-exact agreement with the AOT HLO produced from the Pallas path.

Shared semantic decisions (mirrored in rust/src/squant/mod.rs):

* rounding is round-half-up: rn(x) = floor(x + 0.5);
* sign(0) = 0; a kernel/channel with exactly zero accumulated error is left
  untouched and produces no flip candidate;
* top-k selection breaks |perturbation| ties towards the lower index;
* a flip that would leave the integer grid [qmin, qmax] is infeasible: the
  element is not eligible, and k is clamped to the number of eligible
  elements (the paper assumes an unbounded grid; real fixed-point grids
  saturate, see DESIGN.md);
* SQuant-K is skipped for K == 1 (FC / 1x1 conv), per paper §3.4; the
  flip candidate for such kernels is the element itself;
* SQuant-C flips at most one element per kernel (the Alg. 4 candidate).
"""

from __future__ import annotations

import numpy as np


def rn(x):
    return np.floor(x + 0.5)


def qrange(bits: int):
    qmax = (1 << (bits - 1)) - 1
    return -qmax, qmax


def sign(x: float) -> float:
    return 1.0 if x > 0 else (-1.0 if x < 0 else 0.0)


# ---------------------------------------------------------------------------
# Flip algorithm (paper Algorithm 2) on one row, with Algorithm 4 candidate
# bookkeeping fused (the paper fuses them too, §B.3).
# ---------------------------------------------------------------------------

def flip_row(q, p, e, qmin, qmax):
    """SQuantFlip on one row (kernel): mutates q, p in place.

    Returns (cand_idx, cand_val): the single follow-up flip candidate this
    row exposes to the next granularity level (Algorithm 4), or (-1, 0.0)
    when the row has none.
    """
    sgn = sign(e)
    if sgn == 0.0:
        return -1, 0.0
    elig = (p * sgn > 0) & (q - sgn >= qmin) & (q - sgn <= qmax)
    n_elig = int(elig.sum())
    k = int(rn(abs(e)))
    k = min(k, n_elig)

    # Selection order: eligible elements by descending |p|, ties -> lower idx.
    order = sorted(np.nonzero(elig)[0], key=lambda j: (-abs(p[j]), j))
    for j in order[:k]:
        q[j] -= sgn
        p[j] -= sgn

    over = k > abs(e)
    if over and k >= 1:
        j = order[k - 1]          # last flipped: largest post-flip |p|
        return int(j), float(p[j])
    if not over and k < n_elig:
        j = order[k]              # first unflipped eligible element
        return int(j), float(p[j])
    return -1, 0.0


# ---------------------------------------------------------------------------
# Progressive SQuant (paper Algorithm 1) on one (M, N, K) weight tensor.
# ---------------------------------------------------------------------------

def squant_ref(w, scale, bits, enable_k=True, enable_c=True):
    """Reference progressive SQuant.

    Args:
      w:      float32 array (M, N, K) — output channel, kernel, element.
      scale:  float32 array (M,) — per-output-channel scale.
      bits:   integer bit width (symmetric signed grid).
      enable_k / enable_c: ablation switches (Table 4).

    Returns (q, wq):
      q:  int32 grid values (M, N, K)
      wq: dequantized float32 weights q * scale
    """
    w = np.asarray(w, dtype=np.float32)
    M, N, K = w.shape
    qmin, qmax = qrange(bits)
    t = w / scale[:, None, None].astype(np.float32)
    q = np.clip(rn(t), qmin, qmax).astype(np.float32)
    p = (q - t).astype(np.float32)

    for m in range(M):
        if enable_k and K > 1:
            # SQuant-K per kernel, collecting Algorithm-4 candidates.
            cand_idx = np.full((N,), -1, dtype=np.int64)
            cand_val = np.zeros((N,), dtype=np.float32)
            for n in range(N):
                e = float(p[m, n].sum())
                cand_idx[n], cand_val[n] = flip_row(q[m, n], p[m, n], e, qmin, qmax)
            if enable_c:
                # SQuant-C flips at most one candidate element per kernel.
                a = float(p[m].sum())
                sgn_a = sign(a)
                if sgn_a != 0.0:
                    elig = [n for n in range(N)
                            if cand_idx[n] >= 0 and cand_val[n] * sgn_a > 0]
                    kc = min(int(rn(abs(a))), len(elig))
                    elig.sort(key=lambda n: (-abs(cand_val[n]), n))
                    for n in elig[:kc]:
                        j = cand_idx[n]
                        q[m, n, j] -= sgn_a
                        p[m, n, j] -= sgn_a
        elif enable_c:
            # SQuant-K skipped (K == 1, per paper §3.4, or the E&C ablation):
            # SQuant-C operates directly on the channel's N*K elements as one
            # flip problem (Eq. 11).
            qc = q[m].reshape(-1)
            pc = p[m].reshape(-1)
            flip_row(qc, pc, float(pc.sum()), qmin, qmax)
            q[m] = qc.reshape(N, K)
            p[m] = pc.reshape(N, K)

    wq = q * scale[:, None, None].astype(np.float32)
    return q.astype(np.int32), wq.astype(np.float32)


# ---------------------------------------------------------------------------
# Scales + simple baselines used by pytest cross-checks.
# ---------------------------------------------------------------------------

def channel_scales_ref(w2d, bits):
    _, qmax = qrange(bits)
    absmax = np.abs(w2d).max(axis=1)
    absmax = np.where(absmax <= 0.0, 1.0, absmax)
    return (absmax / qmax).astype(np.float32)


def rtn_ref(w, scale, bits):
    """Round-to-nearest (SQuant-E only) oracle."""
    qmin, qmax = qrange(bits)
    t = w / scale[:, None, None]
    q = np.clip(rn(t), qmin, qmax).astype(np.float32)
    return q.astype(np.int32), (q * scale[:, None, None]).astype(np.float32)


def fake_quant_ref(w2d, scale, bits):
    """Per-row fake-quant oracle for the Pallas fake_quant kernel."""
    qmin, qmax = qrange(bits)
    t = w2d / scale[:, None]
    q = np.clip(rn(t), qmin, qmax)
    return (q * scale[:, None]).astype(np.float32)


def qmatmul_ref(x, q, scale):
    """x [B, IN] @ dequant(q [OUT, IN] * scale [OUT]).T oracle."""
    return (x.astype(np.float64) @ (q * scale[:, None]).astype(np.float64).T).astype(
        np.float32
    )


# ---------------------------------------------------------------------------
# Invariant checkers used by both pytest and hypothesis suites.
# ---------------------------------------------------------------------------

def perturbation(w, q, scale):
    t = w / scale[:, None, None]
    return q - t


def check_invariants(w, q, scale, bits, enable_k=True, enable_c=True, atol=1e-4):
    """Assert the paper's post-conditions (Eq. 9-12) on a SQuant result.

    Returns a dict of the measured maxima so tests can report them.
    """
    qmin, qmax = qrange(bits)
    p = perturbation(np.asarray(w, np.float32), q.astype(np.float32),
                     np.asarray(scale, np.float32))
    out = {}
    assert q.min() >= qmin and q.max() <= qmax, "grid bounds violated"
    t = np.asarray(w, np.float32) / np.asarray(scale, np.float32)[:, None, None]
    saturated = (rn(t) < qmin) | (rn(t) > qmax)
    # Element perturbation bound |dW| < 1 (Eq. 12), unless grid-saturated.
    if (~saturated).any():
        out["max_elem"] = float(np.abs(p[~saturated]).max())
        assert out["max_elem"] < 1.0 + atol, f"|dW|={out['max_elem']}"
    if not saturated.any():
        K = w.shape[2]
        if enable_k and K > 1:
            ase = np.abs(p.sum(axis=-1))
            bound = 1.0 if enable_c else 0.5
            out["max_kernel_ase"] = float(ase.max())
            assert out["max_kernel_ase"] <= bound + atol, (
                f"kernel ASE {out['max_kernel_ase']} > {bound}")
        if enable_c:
            chan = np.abs(p.sum(axis=(1, 2)))
            out["max_channel_ase"] = float(chan.max())
            assert out["max_channel_ase"] <= 0.5 + atol, (
                f"channel ASE {out['max_channel_ase']}")
    return out
