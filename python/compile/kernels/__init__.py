"""L1 Pallas kernels + reference oracles for the SQuant compile pipeline."""

from . import fake_quant, qmatmul, ref, squant_flip  # noqa: F401
