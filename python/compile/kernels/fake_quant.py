"""Pallas per-channel fake-quantization kernel.

Quantize-dequantize of a (rows, cols) weight matrix with one scale per row
(per output channel).  Used by the AOT eval graphs and as the simplest L1
kernel — it doubles as the round-to-nearest (SQuant-E / DFQ) baseline's hot
path on the accelerator.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_BLOCK = 64


def _fq_body(w_ref, s_ref, o_ref, *, qmin: float, qmax: float):
    w = w_ref[...]
    s = s_ref[...][:, None]
    q = jnp.clip(jnp.floor(w / s + 0.5), qmin, qmax)
    o_ref[...] = q * s


@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "row_block"))
def fake_quant(w, s, *, qmin: float, qmax: float,
               row_block: int = DEFAULT_ROW_BLOCK):
    """Per-row fake-quant: clip(rn(w/s), qmin, qmax) * s."""
    r, c = w.shape
    rb = min(row_block, r) if r > 0 else 1
    pad = (-r) % rb
    if pad:
        w = jnp.pad(w, ((0, pad), (0, 0)))
        s = jnp.pad(s, (0, pad), constant_values=1.0)
    rp = w.shape[0]
    out = pl.pallas_call(
        functools.partial(_fq_body, qmin=float(qmin), qmax=float(qmax)),
        grid=(rp // rb,),
        in_specs=[
            pl.BlockSpec((rb, c), lambda i: (i, 0)),
            pl.BlockSpec((rb,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rb, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, c), jnp.float32),
        interpret=True,
    )(w, s)
    return out[:r] if pad else out
