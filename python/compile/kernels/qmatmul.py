"""Pallas quantized matmul: y = x @ (q * s).T with dequantize-on-load.

The inference hot path for FC layers (and im2col'd convolutions) when weights
are stored as integer grid points + per-channel scales.  On a real TPU the
(bb, in) x (ob, in) tile contraction maps onto the MXU systolic array with the
dequantize fused into the load; under interpret=True it lowers to plain HLO
dot + multiply, which is what the CPU PJRT client executes.

Tiling: grid is (B/bb, O/ob); each program instance keeps one x tile and one
dequantized weight tile in VMEM.  The contraction (`in`) dimension is loaded
whole — every layer in the zoo has in <= 1600 floats per row, far under VMEM
budget (see DESIGN.md §Perf for the footprint table).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_B_BLOCK = 32
DEFAULT_O_BLOCK = 32


def _qmm_body(x_ref, q_ref, s_ref, o_ref):
    x = x_ref[...]                       # (bb, in)
    w = q_ref[...] * s_ref[...][:, None]  # dequantize-on-load (ob, in)
    o_ref[...] = x @ w.T


@functools.partial(jax.jit, static_argnames=("b_block", "o_block"))
def qmatmul(x, q, s, *, b_block: int = DEFAULT_B_BLOCK,
            o_block: int = DEFAULT_O_BLOCK):
    """x (B, IN) @ dequant(q (O, IN), s (O,)).T -> (B, O), all float32."""
    b, cin = x.shape
    o, cin2 = q.shape
    assert cin == cin2, (cin, cin2)
    bb = min(b_block, b) if b > 0 else 1
    ob = min(o_block, o) if o > 0 else 1
    pb, po = (-b) % bb, (-o) % ob
    if pb:
        x = jnp.pad(x, ((0, pb), (0, 0)))
    if po:
        q = jnp.pad(q, ((0, po), (0, 0)))
        s = jnp.pad(s, (0, po))
    bp, op_ = x.shape[0], q.shape[0]
    out = pl.pallas_call(
        _qmm_body,
        grid=(bp // bb, op_ // ob),
        in_specs=[
            pl.BlockSpec((bb, cin), lambda i, j: (i, 0)),
            pl.BlockSpec((ob, cin), lambda i, j: (j, 0)),
            pl.BlockSpec((ob,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bb, ob), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, op_), jnp.float32),
        interpret=True,
    )(x, q, s)
    return out[:b, :o]
