"""Pallas implementation of the SQuant flip kernel (paper Algorithms 2 + 4).

One program instance processes a block of independent rows.  A "row" is:

  * SQuant-K stage: one convolution kernel — K = kh*kw elements;
  * SQuant-C stage: one output channel — N candidate perturbations.

This mirrors the paper's GPU mapping (§3.4: "each sub-problem accelerated in
parallel") onto the Pallas grid: instead of one CUDA threadblock per kernel we
tile the (rows, K) perturbation matrix into VMEM-resident row blocks
(BlockSpec), and the per-row top-k is an unrolled masked-argmax loop — K is a
compile-time constant (<= 25 for the zoo), so the loop becomes straight-line
vector code on the MXU-free VPU path.  See DESIGN.md §3 (hardware adaptation)
and §Perf for the block-size study.

Everything here must match ``ref.flip_row`` element-for-element: same
round-half-up, same sign(0)=0 convention, same tie-breaking (argmax returns
the lowest index), same grid-saturation masking.

The kernel is always lowered with ``interpret=True``: CPU PJRT cannot run
Mosaic custom-calls; on a real TPU the same code lowers natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_ROW_BLOCK = 64


def _flip_body(q_ref, p_ref, e_ref, qo_ref, po_ref, ci_ref, cv_ref,
               *, width: int, qmin: float, qmax: float):
    """Process one (RB, width) row block."""
    q = q_ref[...]
    p = p_ref[...]
    e = e_ref[...]
    rb = q.shape[0]

    sgn = jnp.sign(e)[:, None]                       # (RB, 1)
    elig = (p * sgn > 0.0) & (q - sgn >= qmin) & (q - sgn <= qmax)
    n_elig = jnp.sum(elig, axis=1).astype(jnp.float32)
    k = jnp.minimum(jnp.floor(jnp.abs(e) + 0.5), n_elig)  # (RB,)
    over = k > jnp.abs(e)

    score = jnp.where(elig, jnp.abs(p), -1.0)
    rows = jnp.arange(rb)
    cols = jnp.arange(width)[None, :]
    cidx = jnp.full((rb,), -1, dtype=jnp.int32)
    cval = jnp.zeros((rb,), dtype=jnp.float32)

    # Unrolled selection: at step t flip the t-th largest eligible |p|.
    for t in range(width):
        j = jnp.argmax(score, axis=1)                # ties -> lowest index
        valid = score[rows, j] >= 0.0
        do_flip = (jnp.float32(t) < k) & valid
        onehot = (cols == j[:, None])
        step = sgn * do_flip[:, None].astype(jnp.float32)
        q = q - onehot * step
        p = p - onehot * step
        # Algorithm 4 candidate: the k-th flipped element when over-SQuanted
        # (read *after* the flip), the (k+1)-th eligible element otherwise.
        take = jnp.where(over,
                         jnp.float32(t + 1) == k,
                         jnp.float32(t) == k) & valid
        cidx = jnp.where(take, j.astype(jnp.int32), cidx)
        cval = jnp.where(take, p[rows, j], cval)
        score = jnp.where(onehot, -2.0, score)       # consume

    qo_ref[...] = q
    po_ref[...] = p
    ci_ref[...] = cidx
    cv_ref[...] = cval


@functools.partial(jax.jit, static_argnames=("qmin", "qmax", "row_block"))
def flip_rows(q, p, e, *, qmin: float, qmax: float,
              row_block: int = DEFAULT_ROW_BLOCK):
    """Batched SQuantFlip over independent rows.

    Args:
      q: (R, W) float32, integer-valued grid points.
      p: (R, W) float32, perturbation q - w/s.
      e: (R,)  float32, accumulated row perturbation (sum of the *full* row —
         for SQuant-C this is the whole-channel sum, not the candidate sum).
      qmin/qmax: static grid bounds (pass +-inf-ish for the C stage, where
         candidate feasibility was already established).

    Returns (q', p', cand_idx i32 (R,), cand_val f32 (R,)).
    """
    r, width = q.shape
    rb = min(row_block, r) if r > 0 else 1
    pad = (-r) % rb
    if pad:
        # Padded rows have e = 0 -> sign 0 -> nothing eligible, no flips.
        q = jnp.pad(q, ((0, pad), (0, 0)))
        p = jnp.pad(p, ((0, pad), (0, 0)))
        e = jnp.pad(e, (0, pad))
    rp = q.shape[0]
    grid = (rp // rb,)

    body = functools.partial(_flip_body, width=width,
                             qmin=float(qmin), qmax=float(qmax))
    qo, po, ci, cv = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, width), lambda i: (i, 0)),
            pl.BlockSpec((rb, width), lambda i: (i, 0)),
            pl.BlockSpec((rb,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((rb, width), lambda i: (i, 0)),
            pl.BlockSpec((rb, width), lambda i: (i, 0)),
            pl.BlockSpec((rb,), lambda i: (i,)),
            pl.BlockSpec((rb,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rp, width), jnp.float32),
            jax.ShapeDtypeStruct((rp, width), jnp.float32),
            jax.ShapeDtypeStruct((rp,), jnp.int32),
            jax.ShapeDtypeStruct((rp,), jnp.float32),
        ],
        interpret=True,
    )(q, p, e)
    if pad:
        qo, po, ci, cv = qo[:r], po[:r], ci[:r], cv[:r]
    return qo, po, ci, cv
