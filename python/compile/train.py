"""Training pipeline: fit every zoo model on SynthImageNet, export SQNT
containers + dataset bins.

This is a *substrate* for the reproduction (the paper quantizes pre-trained
ImageNet models; we must produce our own converged models — see DESIGN.md
§2).  SGD with Nesterov momentum, cosine LR, light weight decay, BN in
batch-stats mode.  Deterministic given the seeds in `common.py`.

Run via ``python -m compile.train --out ../artifacts`` (normally orchestrated
by ``compile.aot`` / ``make artifacts``).
"""

from __future__ import annotations

import argparse
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets, ir as irmod, model as modelmod, sqnt
from .common import NUM_CLASSES

BATCH = 128
EPOCHS = 10
BASE_LR = 0.08
WEIGHT_DECAY = 1e-4
MOMENTUM = 0.9
TRAIN_SEED = 7


def cross_entropy(logits, labels):
    logz = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logz, labels[:, None], axis=1))


def make_step(ir):
    decay_names = {
        spec["name"] for spec in ir["params"]
        if spec["name"].startswith(("conv_w", "fc_w"))
    }

    def loss_fn(params, x, y):
        logits, new_stats = modelmod.forward_ir(ir, params, x, train=True)
        loss = cross_entropy(logits, y)
        acc = jnp.mean(jnp.argmax(logits, -1) == y)
        return loss, (new_stats, acc)

    @jax.jit
    def step(params, mom, x, y, lr):
        (loss, (new_stats, acc)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, x, y)
        new_params, new_mom = {}, {}
        for k, v in params.items():
            if k in new_stats:  # BN running stats: assigned, not SGD-updated
                new_params[k] = new_stats[k]
                new_mom[k] = mom[k]
                continue
            g = grads[k]
            if k in decay_names:
                g = g + WEIGHT_DECAY * v
            m = MOMENTUM * mom[k] + g
            new_params[k] = v - lr * (MOMENTUM * m + g)  # Nesterov
            new_mom[k] = m
        return new_params, new_mom, loss, acc

    @jax.jit
    def eval_logits(params, x):
        logits, _ = modelmod.forward_ir(ir, params, x, train=False)
        return logits

    return step, eval_logits


def evaluate(eval_logits, params, xs, ys, batch=256):
    correct = 0
    for i in range(0, len(xs), batch):
        logits = eval_logits(params, xs[i:i + batch])
        correct += int((np.argmax(np.asarray(logits), -1) == ys[i:i + batch]).sum())
    return correct / len(xs)


def train_model(name, train_data, test_data, epochs=EPOCHS, log=print):
    ir = irmod.ZOO[name]()
    params = {k: jnp.asarray(v) for k, v in irmod.init_params(ir, TRAIN_SEED).items()}
    mom = {k: jnp.zeros_like(v) for k, v in params.items()}
    step, eval_logits = make_step(ir)

    (xtr, ytr), (xte, yte) = train_data, test_data
    n = len(xtr)
    steps_per_epoch = n // BATCH
    total_steps = epochs * steps_per_epoch
    rng = np.random.default_rng((TRAIN_SEED, hash(name) & 0xFFFF))

    t0 = time.time()
    it = 0
    for ep in range(epochs):
        perm = rng.permutation(n)
        ep_loss, ep_acc = 0.0, 0.0
        for b in range(steps_per_epoch):
            idx = perm[b * BATCH:(b + 1) * BATCH]
            lr = 0.5 * BASE_LR * (1 + math.cos(math.pi * it / total_steps))
            params, mom, loss, acc = step(
                params, mom, jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]),
                jnp.float32(lr))
            ep_loss += float(loss)
            ep_acc += float(acc)
            it += 1
        log(f"  [{name}] epoch {ep + 1}/{epochs} "
            f"loss={ep_loss / steps_per_epoch:.4f} "
            f"acc={ep_acc / steps_per_epoch:.4f} ({time.time() - t0:.0f}s)")

    train_acc = evaluate(eval_logits, params, xtr[:2048], ytr[:2048])
    test_acc = evaluate(eval_logits, params, xte, yte)
    log(f"  [{name}] final train_acc={train_acc:.4f} test_acc={test_acc:.4f}")
    np_params = {k: np.asarray(v) for k, v in params.items()}
    meta = {
        "train_acc": round(train_acc, 4),
        "test_acc": round(test_acc, 4),
        "epochs": epochs,
        "seed": TRAIN_SEED,
    }
    return ir, np_params, meta


def ensure_dataset(outdir, log=print):
    tr_path = os.path.join(outdir, "synthimagenet_train.bin")
    te_path = os.path.join(outdir, "synthimagenet_test.bin")
    if os.path.exists(tr_path) and os.path.exists(te_path):
        log("dataset bins exist, skipping generation")
    else:
        log("generating SynthImageNet ...")
        (xtr, ytr), (xte, yte) = datasets.default_splits()
        datasets.write_dataset_bin(tr_path, xtr, ytr)
        datasets.write_dataset_bin(te_path, xte, yte)
        log(f"wrote {tr_path} ({xtr.shape}) and {te_path} ({xte.shape})")
    # Always return loaded arrays for training.
    def load(path):
        with open(path, "rb") as f:
            assert f.read(4) == b"SDSB"
            ver, n, c, h, w = np.frombuffer(f.read(20), dtype="<u4")
            imgs = np.frombuffer(f.read(n * c * h * w * 4), dtype="<f4").reshape(
                n, c, h, w)
            labels = np.frombuffer(f.read(n * 4), dtype="<u4").astype(np.int32)
        return imgs, labels
    return load(tr_path), load(te_path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(irmod.ZOO.keys()))
    ap.add_argument("--epochs", type=int, default=EPOCHS)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    train_data, test_data = ensure_dataset(args.out)
    for name in args.models.split(","):
        path = os.path.join(args.out, f"{name}.sqnt")
        if os.path.exists(path) and not args.force:
            print(f"{path} exists, skipping")
            continue
        print(f"training {name} ...")
        ir, params, meta = train_model(name, train_data, test_data,
                                       epochs=args.epochs)
        sqnt.write_sqnt(path, ir, params, meta)
        print(f"wrote {path} (test_acc={meta['test_acc']})")


if __name__ == "__main__":
    main()
