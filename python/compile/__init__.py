"""Build-time Python package: JAX model zoo + Pallas kernels + AOT lowering.

Nothing in here runs at serving time — `make artifacts` invokes
``python -m compile.aot`` once, producing HLO text + SQNT containers that the
Rust coordinator consumes.
"""
