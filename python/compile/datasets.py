"""SynthImageNet: a deterministic, procedurally generated image-classification
dataset standing in for ImageNet (which is unavailable in this environment —
see DESIGN.md §2 for the substitution argument).

Ten classes, 32x32x3.  Each class is a parametric texture family:

  * an oriented sinusoidal grating (class-specific orientation + frequency,
    with per-sample jitter and random phase),
  * a class-specific base colour palette (with per-sample jitter),
  * 1-3 soft elliptical blobs at class-biased positions,
  * additive Gaussian pixel noise.

Adjacent classes use adjacent orientations/frequencies so the decision
boundary is genuinely non-trivial; the trained models end up with weight
distributions whose low-bit quantization behaviour mirrors the paper's
regime (graceful at 8 bits, painful at 4, catastrophic for naive rounding
at 3).

The generator is pure numpy + a counter-based RNG seeded from
(DATASET_SEED, split, index), so train/test splits are disjoint and every
regeneration is bit-identical — the Rust side just loads the exported bin.
"""

from __future__ import annotations

import numpy as np

from .common import (
    DATASET_SEED,
    DSET_MAGIC,
    DSET_VERSION,
    IMG_C,
    IMG_H,
    IMG_W,
    NUM_CLASSES,
    TEST_N,
    TRAIN_N,
)

# Class palette anchors (RGB in [0,1]); deliberately overlapping hues.
_PALETTE = np.array(
    [
        [0.85, 0.30, 0.25],
        [0.80, 0.55, 0.20],
        [0.75, 0.75, 0.25],
        [0.40, 0.75, 0.30],
        [0.25, 0.70, 0.60],
        [0.25, 0.55, 0.80],
        [0.35, 0.35, 0.85],
        [0.60, 0.30, 0.80],
        [0.80, 0.30, 0.65],
        [0.55, 0.55, 0.55],
    ],
    dtype=np.float32,
)


def _sample_rng(split: str, idx: int) -> np.random.Generator:
    salt = 0 if split == "train" else 1_000_000_007
    return np.random.default_rng((DATASET_SEED, salt, idx))


def make_image(cls: int, split: str, idx: int) -> np.ndarray:
    """Generate one CHW float32 image in [-1, 1] for class ``cls``."""
    rng = _sample_rng(split, idx)
    yy, xx = np.meshgrid(
        np.linspace(-1.0, 1.0, IMG_H, dtype=np.float32),
        np.linspace(-1.0, 1.0, IMG_W, dtype=np.float32),
        indexing="ij",
    )

    # Oriented grating: classes live 18 degrees apart with +-9 deg jitter,
    # frequency alternates between two bands per class parity.
    theta = np.deg2rad(cls * 18.0 + rng.uniform(-9.0, 9.0))
    freq = 3.0 + (cls % 5) * 1.1 + rng.uniform(-0.5, 0.5)
    phase = rng.uniform(0.0, 2.0 * np.pi)
    u = np.cos(theta) * xx + np.sin(theta) * yy
    grating = 0.5 * np.sin(2.0 * np.pi * freq * u + phase).astype(np.float32)

    # Colour field: palette anchor + jitter, modulated by the grating.
    color = _PALETTE[cls] + rng.uniform(-0.12, 0.12, size=3).astype(np.float32)
    img = np.empty((IMG_C, IMG_H, IMG_W), dtype=np.float32)
    for c in range(IMG_C):
        img[c] = color[c] * (0.6 + 0.4 * grating)

    # Soft elliptical blobs at class-biased positions.
    n_blobs = 1 + int(rng.integers(0, 3))
    bias = np.array(
        [np.cos(cls * 0.63), np.sin(cls * 0.63)], dtype=np.float32
    )
    for _ in range(n_blobs):
        cx = np.clip(0.45 * bias[0] + rng.normal(0.0, 0.35), -0.9, 0.9)
        cy = np.clip(0.45 * bias[1] + rng.normal(0.0, 0.35), -0.9, 0.9)
        sx = rng.uniform(0.08, 0.30)
        sy = rng.uniform(0.08, 0.30)
        amp = rng.uniform(0.25, 0.6) * (1.0 if rng.random() < 0.5 else -1.0)
        blob = amp * np.exp(
            -(((xx - cx) / sx) ** 2 + ((yy - cy) / sy) ** 2)
        ).astype(np.float32)
        ch = int(rng.integers(0, IMG_C))
        img[ch] += blob

    # Pixel noise, then map to roughly [-1, 1].
    img += rng.normal(0.0, 0.15, size=img.shape).astype(np.float32)
    img = 2.0 * img - 1.0
    return np.clip(img, -1.5, 1.5).astype(np.float32)


def make_split(split: str, n: int):
    """Generate (images[N,C,H,W] f32, labels[N] i32); labels round-robin."""
    imgs = np.empty((n, IMG_C, IMG_H, IMG_W), dtype=np.float32)
    labels = np.empty((n,), dtype=np.int32)
    for i in range(n):
        cls = i % NUM_CLASSES
        imgs[i] = make_image(cls, split, i)
        labels[i] = cls
    # Deterministic shuffle so batches are class-mixed.
    rng = np.random.default_rng((DATASET_SEED, 42, 0 if split == "train" else 1))
    perm = rng.permutation(n)
    return imgs[perm], labels[perm]


def write_dataset_bin(path: str, imgs: np.ndarray, labels: np.ndarray) -> None:
    """SDSB container (mirrored by rust/src/io/dataset.rs):

    magic[4] | version u32 | n u32 | c u32 | h u32 | w u32
    | images f32le[n*c*h*w] | labels u32le[n]
    """
    n, c, h, w = imgs.shape
    with open(path, "wb") as f:
        f.write(DSET_MAGIC)
        header = np.array([DSET_VERSION, n, c, h, w], dtype="<u4")
        f.write(header.tobytes())
        f.write(imgs.astype("<f4").tobytes())
        f.write(labels.astype("<u4").tobytes())


def default_splits():
    return make_split("train", TRAIN_N), make_split("test", TEST_N)
