"""Model IR shared between the Python (build-time) and Rust (run-time) sides.

A model is a topologically ordered list of nodes; each node consumes the
outputs of earlier nodes and may reference named parameter tensors.  The same
JSON-serialized IR is embedded in the SQNT weight container and interpreted
by both the JAX executor (`model.py`, for training + AOT lowering) and the
Rust native engine (`rust/src/nn/`).

Ops
---
  input                                  — placeholder, NCHW
  conv2d   {stride, pad, groups}         — weight [O, I/g, KH, KW], bias opt.
  batchnorm{eps}                         — gamma/beta/mean/var, per channel
  relu
  maxpool  {k, s} / avgpool {k, s}
  gap                                    — global average pool -> [N, C]
  linear                                 — weight [O, I], bias opt.
  add                                    — elementwise (residual)
  concat                                 — channel concat
  channel_shuffle {groups}
  flatten

The five architectures are miniature analogs of the paper's evaluation zoo
(ResNet18/50, InceptionV3, SqueezeNext, ShuffleNet) — see DESIGN.md §2 for
why each structural feature is preserved.
"""

from __future__ import annotations

import math
from typing import Optional

from .common import IMG_C, NUM_CLASSES


class Builder:
    """Tiny graph builder: methods append a node and return its id."""

    def __init__(self, name: str):
        self.name = name
        self.nodes = []
        self.params = []  # (name, shape, init) with init in {he, zeros, ones}
        self._uid = 0
        self.input_id = self._node("input", [], {}, {})

    # -- internals ---------------------------------------------------------
    def _node(self, op, inputs, attrs, params) -> int:
        self.nodes.append(
            {"id": len(self.nodes), "op": op, "inputs": list(inputs),
             "attrs": attrs, "params": params}
        )
        return len(self.nodes) - 1

    def _pname(self, kind: str) -> str:
        self._uid += 1
        return f"{kind}{self._uid}"

    def _add_param(self, name, shape, init):
        self.params.append({"name": name, "shape": list(shape), "init": init})

    # -- ops ----------------------------------------------------------------
    def conv(self, x: int, cin: int, cout: int, kh: int, kw: int,
             stride: int = 1, pad: Optional[tuple] = None, groups: int = 1,
             bias: bool = False) -> int:
        if pad is None:
            pad = ((kh - 1) // 2, (kw - 1) // 2)  # per-dim "same" padding
        elif isinstance(pad, int):
            pad = (pad, pad)
        wname = self._pname("conv_w")
        params = {"weight": wname}
        assert cin % groups == 0 and cout % groups == 0
        self._add_param(wname, (cout, cin // groups, kh, kw), "he")
        if bias:
            bname = self._pname("conv_b")
            params["bias"] = bname
            self._add_param(bname, (cout,), "zeros")
        return self._node(
            "conv2d", [x],
            {"stride": stride, "pad": list(pad), "groups": groups,
             "cin": cin, "cout": cout, "kh": kh, "kw": kw},
            params,
        )

    def bn(self, x: int, c: int) -> int:
        g, b = self._pname("bn_g"), self._pname("bn_b")
        m, v = self._pname("bn_m"), self._pname("bn_v")
        self._add_param(g, (c,), "ones")
        self._add_param(b, (c,), "zeros")
        self._add_param(m, (c,), "zeros")
        self._add_param(v, (c,), "ones")
        return self._node(
            "batchnorm", [x], {"eps": 1e-5, "c": c},
            {"gamma": g, "beta": b, "mean": m, "var": v},
        )

    def relu(self, x: int) -> int:
        return self._node("relu", [x], {}, {})

    def maxpool(self, x: int, k: int, s: int) -> int:
        return self._node("maxpool", [x], {"k": k, "s": s}, {})

    def avgpool(self, x: int, k: int, s: int, pad: int = 0) -> int:
        return self._node("avgpool", [x], {"k": k, "s": s, "pad": pad}, {})

    def gap(self, x: int) -> int:
        return self._node("gap", [x], {}, {})

    def linear(self, x: int, cin: int, cout: int, bias: bool = True) -> int:
        wname = self._pname("fc_w")
        params = {"weight": wname}
        self._add_param(wname, (cout, cin), "he")
        if bias:
            bname = self._pname("fc_b")
            params["bias"] = bname
            self._add_param(bname, (cout,), "zeros")
        return self._node("linear", [x],
                          {"cin": cin, "cout": cout}, params)

    def add(self, a: int, b: int) -> int:
        return self._node("add", [a, b], {}, {})

    def concat(self, xs) -> int:
        return self._node("concat", list(xs), {}, {})

    def shuffle(self, x: int, groups: int) -> int:
        return self._node("channel_shuffle", [x], {"groups": groups}, {})

    # -- composite helpers ---------------------------------------------------
    def conv_bn_relu(self, x, cin, cout, kh, kw, stride=1, groups=1, pad=None):
        x = self.conv(x, cin, cout, kh, kw, stride=stride, groups=groups, pad=pad)
        x = self.bn(x, cout)
        return self.relu(x)

    def to_ir(self) -> dict:
        return {
            "name": self.name,
            "input_shape": [IMG_C, 32, 32],
            "num_classes": NUM_CLASSES,
            "nodes": self.nodes,
            "params": self.params,
        }


# ===========================================================================
# Architectures
# ===========================================================================

def mini_resnet18() -> dict:
    """Basic-block residual net: stem + 4 stages x 2 blocks, widths 8..64.

    18 weighted conv/fc layers, mirroring ResNet18's structure (3x3 convs,
    1x1 projection shortcuts on downsample)."""
    b = Builder("miniresnet18")
    widths = [8, 16, 32, 64]
    x = b.conv_bn_relu(b.input_id, IMG_C, widths[0], 3, 3)
    cin = widths[0]
    for si, w in enumerate(widths):
        for bi in range(2):
            stride = 2 if (si > 0 and bi == 0) else 1
            identity = x
            y = b.conv_bn_relu(x, cin, w, 3, 3, stride=stride)
            y = b.conv(y, w, w, 3, 3)
            y = b.bn(y, w)
            if stride != 1 or cin != w:
                identity = b.conv(x, cin, w, 1, 1, stride=stride)
                identity = b.bn(identity, w)
            x = b.relu(b.add(y, identity))
            cin = w
    x = b.gap(x)
    b.linear(x, widths[-1], NUM_CLASSES)
    return b.to_ir()


def mini_resnet50() -> dict:
    """Bottleneck residual net (1x1 -> 3x3 -> 1x1 x4 expansion): heavy on the
    K=1 path which SQuant treats specially (SQuant-K skipped)."""
    b = Builder("miniresnet50")
    widths = [8, 16, 32]
    blocks = [2, 3, 2]
    exp = 4
    x = b.conv_bn_relu(b.input_id, IMG_C, widths[0], 3, 3)
    cin = widths[0]
    for si, (w, nb) in enumerate(zip(widths, blocks)):
        for bi in range(nb):
            stride = 2 if (si > 0 and bi == 0) else 1
            identity = x
            y = b.conv_bn_relu(x, cin, w, 1, 1)
            y = b.conv_bn_relu(y, w, w, 3, 3, stride=stride)
            y = b.conv(y, w, w * exp, 1, 1)
            y = b.bn(y, w * exp)
            if stride != 1 or cin != w * exp:
                identity = b.conv(x, cin, w * exp, 1, 1, stride=stride)
                identity = b.bn(identity, w * exp)
            x = b.relu(b.add(y, identity))
            cin = w * exp
    x = b.gap(x)
    b.linear(x, widths[-1] * exp, NUM_CLASSES)
    return b.to_ir()


def _inception_block(b: Builder, x: int, cin: int, c1, c3r, c3, c5r, c5, cp):
    br1 = b.conv_bn_relu(x, cin, c1, 1, 1)
    br2 = b.conv_bn_relu(x, cin, c3r, 1, 1)
    br2 = b.conv_bn_relu(br2, c3r, c3, 3, 3)
    br3 = b.conv_bn_relu(x, cin, c5r, 1, 1)
    br3 = b.conv_bn_relu(br3, c5r, c5, 5, 5)
    br4 = b.avgpool(x, 3, 1, pad=1)
    br4 = b.conv_bn_relu(br4, cin, cp, 1, 1)
    return b.concat([br1, br2, br3, br4]), c1 + c3 + c5 + cp


def mini_inception() -> dict:
    """GoogLeNet/InceptionV3-style: mixed 1x1/3x3/5x5 branches + concat.

    Exercises K in {1, 9, 25} and the concat path."""
    b = Builder("miniinception")
    x = b.conv_bn_relu(b.input_id, IMG_C, 16, 3, 3)
    x = b.maxpool(x, 2, 2)  # 16x16
    x, c = _inception_block(b, x, 16, 8, 8, 12, 4, 6, 6)   # 32
    x, c = _inception_block(b, x, c, 12, 8, 16, 4, 8, 8)   # 44
    x = b.conv_bn_relu(x, c, 48, 3, 3, stride=2)           # 8x8
    x, c = _inception_block(b, x, 48, 16, 12, 24, 6, 12, 12)  # 64
    x = b.gap(x)
    b.linear(x, c, NUM_CLASSES)
    return b.to_ir()


def mini_squeezenext() -> dict:
    """SqueezeNext-style low-rank blocks: 1x1 reduce, separable 1x3 + 3x1,
    1x1 expand, residual.  Exercises rectangular kernels (K=3)."""
    b = Builder("minisqueezenext")
    x = b.conv_bn_relu(b.input_id, IMG_C, 16, 3, 3)
    cin = 16
    plan = [(16, 1), (16, 1), (32, 2), (32, 1), (64, 2), (64, 1)]
    for cout, stride in plan:
        identity = x
        h = b.conv_bn_relu(x, cin, cout // 2, 1, 1, stride=stride)
        h = b.conv_bn_relu(h, cout // 2, cout // 4, 1, 1)
        h = b.conv_bn_relu(h, cout // 4, cout // 2, 1, 3)
        h = b.conv_bn_relu(h, cout // 2, cout // 2, 3, 1)
        h = b.conv(h, cout // 2, cout, 1, 1)
        h = b.bn(h, cout)
        if stride != 1 or cin != cout:
            identity = b.conv(x, cin, cout, 1, 1, stride=stride)
            identity = b.bn(identity, cout)
        x = b.relu(b.add(h, identity))
        cin = cout
    x = b.gap(x)
    b.linear(x, cin, NUM_CLASSES)
    return b.to_ir()


def mini_shufflenet() -> dict:
    """ShuffleNet-style units: grouped 1x1 conv + channel shuffle + depthwise
    3x3.  Exercises groups>1 and depthwise (N=1) — the degenerate SQuant-C
    case."""
    b = Builder("minishufflenet")
    g = 4
    x = b.conv_bn_relu(b.input_id, IMG_C, 16, 3, 3)
    cin = 16

    def unit(x, cin, cout, stride):
        mid = cout // 4
        h = b.conv_bn_relu(x, cin, mid, 1, 1, groups=g)
        h = b.shuffle(h, g)
        h = b.conv(h, mid, mid, 3, 3, stride=stride, groups=mid)  # depthwise
        h = b.bn(h, mid)
        branch_out = cout - cin if stride == 2 else cout
        h = b.conv(h, mid, branch_out, 1, 1, groups=g)
        h = b.bn(h, branch_out)
        if stride == 2:
            short = b.avgpool(x, 2, 2)
            return b.relu(b.concat([h, short])), cout
        else:
            return b.relu(b.add(h, x)), cout

    x, cin = unit(x, cin, 32, 2)
    x, cin = unit(x, cin, 32, 1)
    x, cin = unit(x, cin, 64, 2)
    x, cin = unit(x, cin, 64, 1)
    x = b.gap(x)
    b.linear(x, cin, NUM_CLASSES)
    return b.to_ir()


ZOO = {
    "miniresnet18": mini_resnet18,
    "miniresnet50": mini_resnet50,
    "miniinception": mini_inception,
    "minisqueezenext": mini_squeezenext,
    "minishufflenet": mini_shufflenet,
}


def quantizable_layers(ir: dict):
    """Yield (node, weight_name, (M, N, K)) for every conv2d/linear node.

    M = output channels, N = input channels per group, K = kh*kw — the
    weight-tensor view SQuant operates on (per-group weights are treated as
    independent channel sets, matching the Rust side)."""
    for node in ir["nodes"]:
        if node["op"] == "conv2d":
            a = node["attrs"]
            yield node, node["params"]["weight"], (
                a["cout"], a["cin"] // a["groups"], a["kh"] * a["kw"])
        elif node["op"] == "linear":
            a = node["attrs"]
            yield node, node["params"]["weight"], (a["cout"], a["cin"], 1)


def init_params(ir: dict, seed: int = 0):
    """He-normal initialization, numpy (deterministic, shared convention)."""
    import numpy as np

    rng = np.random.default_rng((seed, hash(ir["name"]) & 0xFFFF))
    out = {}
    for spec in ir["params"]:
        shape, init = tuple(spec["shape"]), spec["init"]
        if init == "he":
            fan_in = int(math.prod(shape[1:])) if len(shape) > 1 else shape[0]
            std = math.sqrt(2.0 / max(fan_in, 1))
            out[spec["name"]] = rng.normal(0.0, std, size=shape).astype("float32")
        elif init == "ones":
            out[spec["name"]] = np.ones(shape, dtype="float32")
        else:
            out[spec["name"]] = np.zeros(shape, dtype="float32")
    return out
