"""SQNT weight container: the interchange format between the Python build
pipeline and the Rust runtime (mirrored by ``rust/src/io/sqnt.rs``).

Layout (all little-endian):

    magic  b"SQNT"
    version u32
    header_len u32
    header  JSON (utf-8), exactly header_len bytes:
        {
          "name": str, "input_shape": [c,h,w], "num_classes": int,
          "nodes": [...],              # model IR (see ir.py)
          "tensors": [{"name","shape","offset","numel"}, ...],
          "meta": {...}                # train/test acc, seed, etc.
        }
    payload f32le[total_numel]         # tensors concatenated in order

Offsets are in f32 *elements*, not bytes.
"""

from __future__ import annotations

import json
import struct

import numpy as np

from .common import SQNT_MAGIC, SQNT_VERSION


def write_sqnt(path: str, ir: dict, params: dict, meta: dict | None = None):
    tensors = []
    blobs = []
    offset = 0
    for spec in ir["params"]:
        name = spec["name"]
        arr = np.ascontiguousarray(params[name], dtype="<f4")
        assert list(arr.shape) == list(spec["shape"]), (
            name, arr.shape, spec["shape"])
        tensors.append({
            "name": name,
            "shape": list(arr.shape),
            "offset": offset,
            "numel": int(arr.size),
        })
        blobs.append(arr.tobytes())
        offset += int(arr.size)

    header = {
        "name": ir["name"],
        "input_shape": ir["input_shape"],
        "num_classes": ir["num_classes"],
        "nodes": ir["nodes"],
        "tensors": tensors,
        "meta": meta or {},
    }
    hbytes = json.dumps(header, separators=(",", ":")).encode("utf-8")
    with open(path, "wb") as f:
        f.write(SQNT_MAGIC)
        f.write(struct.pack("<II", SQNT_VERSION, len(hbytes)))
        f.write(hbytes)
        for b in blobs:
            f.write(b)


def read_sqnt(path: str):
    """Read back a container (used by pytest round-trip checks)."""
    with open(path, "rb") as f:
        magic = f.read(4)
        assert magic == SQNT_MAGIC, magic
        version, hlen = struct.unpack("<II", f.read(8))
        assert version == SQNT_VERSION
        header = json.loads(f.read(hlen).decode("utf-8"))
        payload = np.frombuffer(f.read(), dtype="<f4")
    params = {}
    for t in header["tensors"]:
        arr = payload[t["offset"]:t["offset"] + t["numel"]]
        params[t["name"]] = arr.reshape(t["shape"]).copy()
    return header, params
