"""L2: JAX execution of the model IR + the SQuant computation graph.

Two roles:

1. **Model zoo forward** — interprets the IR from `ir.py` with
   `lax.conv_general_dilated` etc.  Used for training (`train.py`, BN in
   batch-stats mode with autodiff) and AOT-lowered in eval mode with all
   parameters as HLO inputs (so the Rust side can feed *any* — e.g.
   quantized — weights without re-lowering).

2. **SQuant graph** — the progressive E→K→C algorithm as a pure JAX function
   calling the L1 Pallas flip kernel, fully vectorized over channels and
   kernels.  `aot.py` lowers one HLO per distinct (M, N, K) weight shape in
   the zoo; the Rust coordinator can then offload layer quantization to the
   PJRT device.  Tested bit-exact against `kernels.ref.squant_ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .common import rn, qrange
from .kernels import qmatmul as qmm
from .kernels import squant_flip

# ---------------------------------------------------------------------------
# IR executor
# ---------------------------------------------------------------------------

BN_MOMENTUM = 0.9


def forward_ir(ir, params, x, train=False, use_pallas_fc=False):
    """Run the model IR.

    Returns (logits, new_running_stats) where new_running_stats is a dict of
    updated BN running mean/var tensors (empty in eval mode).
    """
    vals = {}
    new_stats = {}
    for node in ir["nodes"]:
        op = node["op"]
        ins = [vals[i] for i in node["inputs"]]
        a = node["attrs"]
        prm = node["params"]
        if op == "input":
            out = x
        elif op == "conv2d":
            w = params[prm["weight"]]
            ph, pw = a["pad"]
            out = lax.conv_general_dilated(
                ins[0], w,
                window_strides=(a["stride"], a["stride"]),
                padding=[(ph, ph), (pw, pw)],
                feature_group_count=a["groups"],
                dimension_numbers=("NCHW", "OIHW", "NCHW"),
            )
            if "bias" in prm:
                out = out + params[prm["bias"]][None, :, None, None]
        elif op == "batchnorm":
            g = params[prm["gamma"]][None, :, None, None]
            b = params[prm["beta"]][None, :, None, None]
            if train:
                mu = jnp.mean(ins[0], axis=(0, 2, 3))
                var = jnp.var(ins[0], axis=(0, 2, 3))
                new_stats[prm["mean"]] = (
                    BN_MOMENTUM * params[prm["mean"]] + (1 - BN_MOMENTUM) * mu)
                new_stats[prm["var"]] = (
                    BN_MOMENTUM * params[prm["var"]] + (1 - BN_MOMENTUM) * var)
            else:
                mu = params[prm["mean"]]
                var = params[prm["var"]]
            inv = lax.rsqrt(var + a["eps"])[None, :, None, None]
            out = (ins[0] - mu[None, :, None, None]) * inv * g + b
        elif op == "relu":
            out = jnp.maximum(ins[0], 0.0)
        elif op == "maxpool":
            k, s = a["k"], a["s"]
            out = lax.reduce_window(
                ins[0], -jnp.inf, lax.max, (1, 1, k, k), (1, 1, s, s), "VALID")
        elif op == "avgpool":
            k, s, pad = a["k"], a["s"], a.get("pad", 0)
            summed = lax.reduce_window(
                ins[0], 0.0, lax.add, (1, 1, k, k), (1, 1, s, s),
                [(0, 0), (0, 0), (pad, pad), (pad, pad)])
            out = summed / float(k * k)  # count_include_pad=True convention
        elif op == "gap":
            out = jnp.mean(ins[0], axis=(2, 3))
        elif op == "linear":
            w = params[prm["weight"]]
            if use_pallas_fc:
                out = qmm.qmatmul(ins[0], w, jnp.ones((w.shape[0],), jnp.float32))
            else:
                out = ins[0] @ w.T
            if "bias" in prm:
                out = out + params[prm["bias"]][None, :]
        elif op == "add":
            out = ins[0] + ins[1]
        elif op == "concat":
            out = jnp.concatenate(ins, axis=1)
        elif op == "channel_shuffle":
            g = a["groups"]
            n, c, h, w_ = ins[0].shape
            out = ins[0].reshape(n, g, c // g, h, w_).swapaxes(1, 2).reshape(
                n, c, h, w_)
        elif op == "flatten":
            out = ins[0].reshape(ins[0].shape[0], -1)
        else:
            raise ValueError(f"unknown op {op}")
        vals[node["id"]] = out
    return vals[len(ir["nodes"]) - 1], new_stats


def forward_flat(ir, x, flat_params, use_pallas_fc=False):
    """Eval-mode forward with parameters as a flat list in ir['params'] order
    — the signature the AOT HLO exposes to the Rust runtime."""
    params = {spec["name"]: t for spec, t in zip(ir["params"], flat_params)}
    logits, _ = forward_ir(ir, params, x, train=False,
                           use_pallas_fc=use_pallas_fc)
    return (logits,)


# ---------------------------------------------------------------------------
# Vectorized SQuant graph (calls the Pallas flip kernel)
# ---------------------------------------------------------------------------

def squant_graph(w, scale, *, bits: int):
    """Progressive SQuant (E→K→C) on a (M, N, K) weight tensor.

    Fully shape-static JAX: `aot.py` lowers one HLO per (M, N, K, bits).
    Returns (q, wq): integer grid values (as f32) and dequantized weights.
    """
    m, n, k = w.shape
    qmin, qmax = qrange(bits)
    t = w / scale[:, None, None]
    q = jnp.clip(rn(t), qmin, qmax)
    p = q - t

    if k > 1:
        # --- SQuant-K over M*N kernel rows --------------------------------
        qr = q.reshape(m * n, k)
        pr = p.reshape(m * n, k)
        e = jnp.sum(pr, axis=1)
        qr, pr, cidx, cval = squant_flip.flip_rows(
            qr, pr, e, qmin=float(qmin), qmax=float(qmax))
        q = qr.reshape(m, n, k)
        p = pr.reshape(m, n, k)
        cidx = cidx.reshape(m, n)
        cval = cval.reshape(m, n)

        # --- SQuant-C over channels: rows of N candidate values -----------
        # Invalid candidates (idx < 0) carry val 0 -> never eligible.
        a = jnp.sum(p, axis=(1, 2))
        qv = jnp.zeros((m, n), jnp.float32)  # virtual grid, unconstrained
        _, pv, _, _ = squant_flip.flip_rows(
            qv, cval, a, qmin=-1e30, qmax=1e30)
        flipped = pv != cval                              # (m, n)
        sgn_a = jnp.sign(a)[:, None]                      # (m, 1)
        onehot = (jnp.arange(k)[None, None, :] ==
                  jnp.maximum(cidx, 0)[:, :, None])       # (m, n, k)
        delta = onehot * (flipped * sgn_a)[:, :, None]
        q = q - delta
    else:
        # K == 1: SQuant-K skipped; SQuant-C flips elements directly over the
        # flattened channel (paper §3.4).
        qr = q.reshape(m, n)
        pr = p.reshape(m, n)
        a = jnp.sum(pr, axis=1)
        qr, pr, _, _ = squant_flip.flip_rows(
            qr, pr, a, qmin=float(qmin), qmax=float(qmax))
        q = qr.reshape(m, n, 1)

    wq = q * scale[:, None, None]
    return q, wq


@functools.partial(jax.jit, static_argnames=("bits",))
def squant_jit(w, scale, *, bits: int):
    return squant_graph(w, scale, bits=bits)
