"""AOT lowering: JAX → HLO text artifacts consumed by the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/README.md.

Artifacts produced in --out (default ../artifacts):

  synthimagenet_{train,test}.bin       dataset (via compile.train)
  <model>.sqnt                         trained weights + IR (via compile.train)
  <model>_fwd_b{B}.hlo.txt             eval forward, params as HLO inputs
  squant_m{M}_n{N}_k{K}_b{bits}.hlo.txt  SQuant E→K→C for one weight shape
  manifest.json                        index of everything above

`make artifacts` is incremental: existing files are kept unless --force.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import ir as irmod, model as modelmod, sqnt, train as trainmod

FWD_BATCHES = (1, 256)
SQUANT_BITS = (4, 8)
# SQuant AOT offload artifacts are lowered for this model's layer shapes (the
# cross-validation + offload demo target); the Rust native path covers every
# model and bit-width.
SQUANT_AOT_MODEL = "miniresnet18"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_forward(ir, batch: int) -> str:
    c, h, w = ir["input_shape"]
    x_spec = jax.ShapeDtypeStruct((batch, c, h, w), jnp.float32)
    p_specs = [jax.ShapeDtypeStruct(tuple(s["shape"]), jnp.float32)
               for s in ir["params"]]

    def fn(x, *params):
        return modelmod.forward_flat(ir, x, params, use_pallas_fc=True)

    return to_hlo_text(jax.jit(fn).lower(x_spec, *p_specs))


def lower_squant(m: int, n: int, k: int, bits: int) -> str:
    w_spec = jax.ShapeDtypeStruct((m, n, k), jnp.float32)
    s_spec = jax.ShapeDtypeStruct((m,), jnp.float32)

    def fn(w, s):
        return modelmod.squant_graph(w, s, bits=bits)

    return to_hlo_text(jax.jit(fn).lower(w_spec, s_spec))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-train", action="store_true",
                    help="fail if weights are missing instead of training")
    ap.add_argument("--epochs", type=int, default=trainmod.EPOCHS)
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    # ---- 1. dataset + trained models (compile.train) ----------------------
    train_data, test_data = trainmod.ensure_dataset(out)
    for name in irmod.ZOO:
        path = os.path.join(out, f"{name}.sqnt")
        if os.path.exists(path) and not args.force:
            continue
        if args.skip_train:
            raise FileNotFoundError(path)
        print(f"training {name} ...")
        ir, params, meta = trainmod.train_model(
            name, train_data, test_data, epochs=args.epochs)
        sqnt.write_sqnt(path, ir, params, meta)
        print(f"wrote {path} (test_acc={meta['test_acc']})")

    manifest = {"models": {}, "squant": [], "dataset": {
        "train": "synthimagenet_train.bin", "test": "synthimagenet_test.bin"}}

    # ---- 2. forward HLOs ---------------------------------------------------
    for name in irmod.ZOO:
        header, _ = sqnt.read_sqnt(os.path.join(out, f"{name}.sqnt"))
        ir = {k: header[k] for k in
              ("name", "input_shape", "num_classes", "nodes")}
        ir["params"] = [{"name": t["name"], "shape": t["shape"]}
                        for t in header["tensors"]]
        entry = {"sqnt": f"{name}.sqnt", "forward": {},
                 "param_order": [t["name"] for t in header["tensors"]],
                 "meta": header["meta"]}
        for b in FWD_BATCHES:
            fname = f"{name}_fwd_b{b}.hlo.txt"
            fpath = os.path.join(out, fname)
            if not os.path.exists(fpath) or args.force:
                print(f"lowering {fname} ...")
                with open(fpath, "w") as f:
                    f.write(lower_forward(ir, b))
            entry["forward"][str(b)] = fname
        manifest["models"][name] = entry

    # ---- 3. SQuant offload HLOs -------------------------------------------
    header, _ = sqnt.read_sqnt(os.path.join(out, f"{SQUANT_AOT_MODEL}.sqnt"))
    ir = {k: header[k] for k in ("name", "input_shape", "num_classes", "nodes")}
    ir["params"] = [{"name": t["name"], "shape": t["shape"]}
                    for t in header["tensors"]]
    shapes = sorted({mnk for _, _, mnk in irmod.quantizable_layers(ir)})
    for (m, n, k) in shapes:
        for bits in SQUANT_BITS:
            fname = f"squant_m{m}_n{n}_k{k}_b{bits}.hlo.txt"
            fpath = os.path.join(out, fname)
            if not os.path.exists(fpath) or args.force:
                print(f"lowering {fname} ...")
                with open(fpath, "w") as f:
                    f.write(lower_squant(m, n, k, bits))
            manifest["squant"].append(
                {"m": m, "n": n, "k": k, "bits": bits, "file": fname})

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"manifest written: {len(manifest['models'])} models, "
          f"{len(manifest['squant'])} squant artifacts")


if __name__ == "__main__":
    main()
