//! API-compatible stub of the `xla` / PJRT bindings used by
//! `squant::runtime`.
//!
//! The native XLA runtime is not vendored in this repository, so this crate
//! provides just enough surface for the dependent code to compile.
//! [`PjRtClient::cpu`] always fails, which the runtime layer surfaces as
//! "PJRT platform: unavailable"; every downstream method is therefore
//! unreachable in practice but still type-checks against the real bindings'
//! signatures.  Handle types carry an `Rc` marker so they stay `!Send`/
//! `!Sync`, matching the real crate's thread-confinement contract — code
//! that compiles against the stub won't break when the real bindings are
//! swapped in.

use std::path::Path;
use std::rc::Rc;

/// Error type; the dependent code only formats it with `{:?}`.
#[derive(Clone, Debug)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what}: PJRT native runtime not vendored (xla stub crate)"
    )))
}

/// PJRT client handle.  [`PjRtClient::cpu`] always errors in the stub.
pub struct PjRtClient {
    _not_send: Rc<()>,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("PjRtClient::compile")
    }
}

/// Parsed HLO module (never constructed by the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(
        _path: impl AsRef<Path>,
    ) -> Result<HloModuleProto, XlaError> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _not_send: Rc<()>,
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer {
    _not_send: Rc<()>,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host-side literal (tensor value).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        unavailable("Literal::to_tuple")
    }

    pub fn array_shape(&self) -> Result<ArrayShape, XlaError> {
        unavailable("Literal::array_shape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable("Literal::to_vec")
    }
}

/// Array shape of a literal.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(format!("{err:?}").contains("not vendored"));
    }

    #[test]
    fn literal_constructors_exist() {
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
